"""Drop-in `hypothesis` subset for offline environments.

The test suite property-tests kernels and models with
`@given(...)`/`@settings(...)` over a handful of strategy types.  The real
`hypothesis` package is not installable in the offline CI container, so this
module re-exports the genuine library when it is importable and otherwise
provides a deterministic fallback: each `@given` test is executed
`max_examples` times with draws taken from a seeded `numpy` generator, so a
run is reproducible example-for-example across machines.

Only the strategies the suite uses are implemented (`sampled_from`,
`integers`, `booleans`); extend `_Strategies` if a test needs more.
"""
from __future__ import annotations

# the whole point of this module is re-exporting these names; __all__
# marks them used for pyflakes (which, unlike flake8, ignores noqa)
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "strategies"]

try:                                    # pragma: no cover - env-dependent
    from hypothesis import given, settings, strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng: np.random.Generator):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    strategies = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Accepts (and ignores) hypothesis-only knobs like `deadline`."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for i in range(n):
                    # one independent, fixed stream per example index
                    rng = np.random.default_rng(0xB2A3AC + 7919 * i)
                    drawn = {k: s.sample(rng) for k, s in strats.items()}
                    fn(**drawn)
            # metadata only — functools.wraps would copy the signature and
            # make pytest look up the strategy params as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
