"""Routing-invariant property tests for the MoE capacity dispatch.

The capacity bookkeeping (rank-in-expert, keep masks, per-source C_src
splits) is pure integer accounting that both `models.moe.moe` and
`parallel.ep.ep_moe` build on; these properties pin its contract over
random (T, E, k, capacity_factor):

  * token conservation — kept + dropped == T·k, and kept is exactly
    Σ_e min(count_e, C);
  * rank-in-expert is a permutation of 0..count_e-1 within each expert,
    for BOTH the argsort and the one-hot-cumsum implementations;
  * dispatch is invariant under token permutation up to the documented
    tie-break (earlier tokens win capacity): per-expert kept/dropped
    COUNTS never change, only which tokens fill the slots;
  * drop counts are monotonically non-increasing in capacity_factor;
  * per-source (GShard) capacity keeps exactly Σ_s Σ_e min(count_se, C_src)
    with C_src = ceil(C / ep_size) — shard-local drops only.

hypothesis-optional per ROADMAP policy: `_hypothesis_compat` replays a
deterministic example grid when the real library is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.models.moe import (_rank_in_expert_cumsum, _rank_in_expert_sort,
                              moe_capacity)

jax.config.update("jax_platform_name", "cpu")

CF_GRID = [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0]


def _route(seed: int, T: int, E: int, k: int) -> np.ndarray:
    """Realistic assignments: top-k over random logits (distinct experts
    per token, like the real router) → flat (T*k,) expert ids."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))
    _, top_i = jax.lax.top_k(logits, k)
    return np.asarray(top_i.reshape(T * k))


def _rank_cumsum(a: np.ndarray, E: int) -> np.ndarray:
    """The moe_dispatch="cumsum" rank path — the REAL one, imported, so
    changes to moe() can't silently drift out from under this suite."""
    return np.asarray(_rank_in_expert_cumsum(jnp.asarray(a), E))


def _rank_sort(a: np.ndarray, E: int) -> np.ndarray:
    return np.asarray(_rank_in_expert_sort(jnp.asarray(a), E))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), T=st.sampled_from([8, 16, 24, 32]),
       E=st.sampled_from([2, 4, 8]), k=st.integers(1, 4),
       cf=st.sampled_from(CF_GRID))
def test_routing_token_conserving(seed, T, E, k, cf):
    k = min(k, E)
    a = _route(seed, T, E, k)
    C = moe_capacity(T, E, k, cf)
    keep = _rank_sort(a, E) < C
    kept, dropped = int(keep.sum()), int((~keep).sum())
    assert kept + dropped == T * k
    counts = np.bincount(a, minlength=E)
    assert kept == int(np.minimum(counts, C).sum())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), T=st.sampled_from([8, 16, 24, 32]),
       E=st.sampled_from([2, 4, 8]), k=st.integers(1, 4))
def test_rank_in_expert_is_permutation_both_paths(seed, T, E, k):
    k = min(k, E)
    a = _route(seed, T, E, k)
    for pos in (_rank_sort(a, E), _rank_cumsum(a, E)):
        for e in range(E):
            ranks = np.sort(pos[a == e])
            np.testing.assert_array_equal(ranks, np.arange(ranks.size))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), T=st.sampled_from([8, 16, 24, 32]),
       E=st.sampled_from([2, 4, 8]), k=st.integers(1, 4),
       cf=st.sampled_from(CF_GRID))
def test_dispatch_invariant_under_token_permutation(seed, T, E, k, cf):
    """Permuting the token order permutes WHICH tokens win capacity (the
    documented tie-break: earlier (token, k-slot) assignments win), but the
    per-expert kept and dropped counts are order-free: min(count_e, C)."""
    k = min(k, E)
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(T, E)).astype(np.float32)
    perm = rng.permutation(T)
    C = moe_capacity(T, E, k, cf)

    def kept_per_expert(lg):
        _, top_i = jax.lax.top_k(jnp.asarray(lg), k)
        a = np.asarray(top_i.reshape(T * k))
        keep = _rank_sort(a, E) < C
        return np.bincount(a[keep], minlength=E), \
            np.bincount(a[~keep], minlength=E)

    kept0, drop0 = kept_per_expert(logits)
    kept1, drop1 = kept_per_expert(logits[perm])
    np.testing.assert_array_equal(kept0, kept1)
    np.testing.assert_array_equal(drop0, drop1)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), T=st.sampled_from([8, 16, 24, 32]),
       E=st.sampled_from([2, 4, 8]), k=st.integers(1, 4))
def test_drops_monotone_in_capacity_factor(seed, T, E, k):
    k = min(k, E)
    a = _route(seed, T, E, k)
    pos = _rank_sort(a, E)
    drops = [int((pos >= moe_capacity(T, E, k, cf)).sum())
             for cf in sorted(CF_GRID)]
    assert all(d0 >= d1 for d0, d1 in zip(drops, drops[1:])), drops
    # and the no-drop capacity really keeps everything
    assert int((pos >= moe_capacity(T, E, k, E / k)).sum()) == 0


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), T=st.sampled_from([8, 16, 24, 32]),
       E=st.sampled_from([2, 4, 8]), k=st.integers(1, 4),
       n=st.sampled_from([1, 2, 4]), cf=st.sampled_from(CF_GRID))
def test_per_source_capacity_bookkeeping(seed, T, E, k, n, cf):
    """The GShard per-source rule (shard-local ranks vs C_src = ceil(C/n))
    keeps exactly Σ_s Σ_e min(count_se, C_src) tokens — drops never depend
    on other shards' occupancy.  n=1 degenerates to the global rule."""
    k = min(k, E)
    a = _route(seed, T, E, k)
    C = moe_capacity(T, E, k, cf)
    Cs = -(-C // n)
    blocks = a.reshape(n, (T // n) * k)
    kept = sum(int((_rank_sort(b, E) < Cs).sum()) for b in blocks)
    want = sum(int(np.minimum(np.bincount(b, minlength=E), Cs).sum())
               for b in blocks)
    assert kept == want
    if n == 1:
        assert kept == int(np.minimum(np.bincount(a, minlength=E), C).sum())
