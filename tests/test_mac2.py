"""Algorithm 1 (hybrid bit-serial & bit-parallel MAC2) — exactness tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import mac2 as m
from repro.core.quant import qrange

jax.config.update("jax_platform_name", "cpu")

BITS = [2, 4, 8]


def rand_ints(rng, bits, shape, signed=True):
    lo, hi = qrange(bits)
    if not signed:
        lo, hi = 0, (1 << bits) - 1
    return rng.integers(lo, hi + 1, size=shape, dtype=np.int32)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("signed", [True, False])
def test_mac2_exhaustive_small(bits, signed):
    """2-bit and 4-bit: exhaustive over all (w1,w2,i1,i2) combos; 8-bit sampled."""
    lo, hi = qrange(bits)
    if not signed:
        lo, hi = 0, (1 << bits) - 1
    if bits <= 4:
        vals = np.arange(lo, hi + 1, dtype=np.int32)
    else:
        vals = np.array([lo, lo + 1, -3, -1, 0, 1, 2, 77, hi - 1, hi] if signed
                        else [0, 1, 2, 77, 128, 200, hi], dtype=np.int32)
    W1, W2, I1, I2 = np.meshgrid(vals, vals, vals, vals, indexing="ij")
    got = m.mac2(jnp.asarray(W1.ravel()), jnp.asarray(W2.ravel()),
                 jnp.asarray(I1.ravel()), jnp.asarray(I2.ravel()),
                 bits=bits, signed_inputs=signed)
    want = W1.ravel() * I1.ravel() + W2.ravel() * I2.ravel()
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1),
       rows=st.integers(1, 40))
def test_mac2_lanes_property(bits, seed, rows):
    """Vectorized lanes (the 160-bit SIMD row) match the integer oracle."""
    rng = np.random.default_rng(seed)
    w1 = rand_ints(rng, bits, (rows,))
    w2 = rand_ints(rng, bits, (rows,))
    i1, i2 = rand_ints(rng, bits, (2,))
    got = m.mac2(jnp.asarray(w1), jnp.asarray(w2), int(i1), int(i2), bits=bits)
    np.testing.assert_array_equal(np.asarray(got), w1 * i1 + w2 * i2)


@settings(max_examples=25, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1),
       rows=st.integers(1, 16), colpairs=st.integers(1, 32))
def test_mac2_mvm_property(bits, seed, rows, colpairs):
    """Chained MAC2s with in-place accumulation == w @ x (paper Fig 2)."""
    rng = np.random.default_rng(seed)
    cols = 2 * colpairs
    w = rand_ints(rng, bits, (rows, cols))
    x = rand_ints(rng, bits, (cols,))
    got = m.mac2_mvm(jnp.asarray(w), jnp.asarray(x), bits=bits)
    np.testing.assert_array_equal(np.asarray(got), w @ x)


@pytest.mark.parametrize("bits", BITS)
def test_accumulator_headroom(bits):
    """§III-C2: lane width 8/16/32 holds a single MAC2 (needs ≤ 2n+1 bits)."""
    lo, hi = qrange(bits)
    worst = 2 * lo * lo  # max |W*I| sum magnitude
    assert abs(worst) < 2 ** (m.lane_width(bits) - 1)
