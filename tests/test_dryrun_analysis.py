"""Unit tests for the dry-run's HLO analysis helpers (no 512-device mesh —
pure text/number functions; the launch path itself is covered by the fleet
results in results/dryrun)."""
import json
import glob

import pytest


def _import_dryrun():
    """Import repro.launch.dryrun WITHOUT letting its XLA_FLAGS line affect
    this process's already-initialized jax (device count is locked at first
    jax init, so importing after jax is already up is harmless)."""
    import jax
    jax.devices()
    from repro.launch import dryrun
    return dryrun


def test_collective_bytes_parser():
    d = _import_dryrun()
    hlo = """
  %all-gather = f32[4096,512]{1,0} all-gather(%x), channel_id=1
  %all-reduce.1 = bf16[16,1024]{1,0} all-reduce(%y), channel_id=3
  %rs = f32[8,2]{1,0} reduce-scatter(%z), channel_id=4
  %notacollective = f32[9,9]{1,0} add(%a, %b)
  %ag2 = s8[100]{0} all-gather(%w), channel_id=7
  %cp = bf16[4,4]{1,0} collective-permute(%q), channel_id=9
"""
    got = d.collective_bytes(hlo)
    assert got["all-gather"] == 4096 * 512 * 4 + 100
    assert got["all-reduce"] == 16 * 1024 * 2
    assert got["reduce-scatter"] == 8 * 2 * 4
    assert got["collective-permute"] == 4 * 4 * 2
    assert "add" not in got


def test_collective_bytes_async_start_ops():
    d = _import_dryrun()
    hlo = "  %ags = (f32[8],f32[16]) all-gather-start(%x), channel_id=1\n" \
          "  %ag = f32[32,2]{1,0} all-gather(%x), channel_id=2\n"
    got = d.collective_bytes(hlo)
    assert got["all-gather"] >= 32 * 2 * 4


def test_slstm_correction_only_for_slstm_archs():
    d = _import_dryrun()
    from repro.configs import get_config
    info_train = {"kind": "train", "seq": 4096, "batch": 256}
    assert d._slstm_scan_correction(get_config("granite-8b"),
                                    info_train) == 0.0
    x = get_config("xlstm-1.3b")
    corr = d._slstm_scan_correction(x, info_train)
    # 6 slstm layers × (S-1) steps × 2·B·d·4d × 4 (fwd+remat+bwd)
    want = 6 * 4095 * 2 * 256 * 2048 * (4 * 2048) * 4
    assert corr == float(want)
    assert d._slstm_scan_correction(
        x, {"kind": "decode", "seq": 32768, "batch": 128}) == 0.0


def test_variants_table_is_wellformed():
    d = _import_dryrun()
    for name, (transform, rules_fn, qbits) in d.VARIANTS.items():
        from repro.configs import get_config
        cfg = transform(get_config("granite-8b"))
        assert cfg.num_layers == 36
        if rules_fn is not None:
            rules = rules_fn(False)
            assert "batch" in rules
        assert qbits in (0, 2, 4, 8)


@pytest.mark.skipif(not glob.glob("results/dryrun/*__pod.json"),
                    reason="no fleet results yet")
def test_fleet_records_consistent():
    """Every completed cell's roofline terms are consistent with its raw
    counters (recomputable from the stored record)."""
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    for f in glob.glob("results/dryrun/*__pod.json"):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        assert abs(ro["compute_s"]
                   - r["hlo_flops_per_dev"] / PEAK_FLOPS_BF16) < 1e-9
        assert abs(ro["memory_s"] - r["hlo_bytes_per_dev"] / HBM_BW) < 1e-9
        assert abs(ro["collective_s"]
                   - r["collective_bytes_total_per_dev"] / ICI_BW) < 1e-9
        assert ro["dominant"] in ("compute", "memory", "collective")
        assert 0 < ro["roofline_fraction"] <= 1.0
        assert sum(r["collective_bytes_per_dev"].values()) == \
            r["collective_bytes_total_per_dev"]
