"""Self-speculative decoding (runtime/speculate.py + sampling.spec_verify
+ the engine's speculative tick).

The load-bearing contract is invariant A1: under greedy sampling the
emitted streams are bit-identical to non-speculative decoding — whatever
the drafter proposes, however many drafts get rejected, wherever the
rejection lands relative to a page boundary.  This file proves it across
{spec on, off} x {paged, dense} x {prefix cache on, off} on the gqa, mla
and int8-KV cache architectures, with `check_invariants=True` so every
speculative rollback round also re-proves the HostPool mirror == device
allocator equality.  The drafter itself is property-tested against a
pure-Python replay (invariant A5: the device table is deterministic,
last-write-wins), and the accept rule is unit-tested directly on both the
greedy and rejection-sampling paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import get_config
from repro.models import model as M
from repro.runtime import speculate as spc
from repro.runtime import sampling as smp
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")

ARCHS = {
    "gqa": ("granite-8b", {}),
    "mla": ("minicpm3-4b", {}),
    "int8kv": ("granite-8b", {"quant_kv": True}),
}

_CACHE = {}


def _setup(name):
    if name not in _CACHE:
        arch, over = ARCHS[name]
        cfg = get_config(arch, smoke=True)
        if over:
            cfg = cfg.replace(**over)
        _CACHE[name] = (cfg, M.init_params(cfg, jax.random.PRNGKey(0)))
    return _CACHE[name]


# --- drafter vs pure-Python reference (invariant A5) ------------------------

def _ref_fnv(ctx):
    h = spc.FNV_OFFSET
    for t in ctx:
        h = ((h ^ (int(t) + 1)) * spc.FNV_PRIME) & 0xFFFFFFFF
    return h


def _ref_replay(tokens, ngram, table):
    """Reference table build: feed tokens in order, last write wins."""
    keys = [0] * table
    nexts = [0] * table
    hist = [-1] * (ngram - 1)
    for t in tokens:
        h = _ref_fnv(hist)
        idx = h % table
        keys[idx] = h
        nexts[idx] = int(t)
        hist = hist[1:] + [int(t)]
    return keys, nexts, hist


def _ref_propose(keys, nexts, hist, table, draft_len):
    hist = list(hist)
    out = []
    for _ in range(draft_len):
        h = _ref_fnv(hist)
        idx = h % table
        g = nexts[idx] if keys[idx] == h else hist[-1]
        out.append(g)
        hist = hist[1:] + [g]
    return out


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(1, 40),
       ngram=st.integers(2, 4),
       table=st.sampled_from([8, 64]))
def test_ngram_table_matches_reference_replay(seed, n, ngram, table):
    """Device observe/propose bit-match the pure-Python replay — including
    bucket collisions (table=8 forces them), so the scan's last-write-wins
    ordering is what actually lands (a duplicate-index scatter would be
    nondeterministic here)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 50, size=n)
    dr = spc.NGramDrafter(ngram=ngram, table=table)
    ds = dr.init_state(1)
    ds = dr.observe(ds, jnp.asarray(toks[None], jnp.int32),
                    jnp.ones((1, n), bool))
    keys, nexts, hist = _ref_replay(toks, ngram, table)
    assert np.asarray(ds.keys)[0].tolist() == keys
    assert np.asarray(ds.nexts)[0].tolist() == nexts
    assert np.asarray(ds.hist)[0].tolist() == hist
    drafts = np.asarray(dr.propose(ds, 4))[0].tolist()
    assert drafts == _ref_propose(keys, nexts, hist, table, 4)


def test_ngram_observe_mask_and_reset():
    """Masked positions must not insert or shift history, and reset must
    clear exactly the masked slots."""
    dr = spc.NGramDrafter(ngram=2, table=16)
    ds = dr.observe(dr.init_state(2),
                    jnp.asarray([[3, 4, 5], [3, 9, 5]], jnp.int32),
                    jnp.asarray([[True, True, True],
                                 [True, False, True]]))
    # slot 1 skipped token 9: its table equals replaying [3, 5]
    k0, n0, h0 = _ref_replay([3, 4, 5], 2, 16)
    k1, n1, h1 = _ref_replay([3, 5], 2, 16)
    assert np.asarray(ds.keys)[0].tolist() == k0
    assert np.asarray(ds.keys)[1].tolist() == k1
    assert np.asarray(ds.nexts)[1].tolist() == n1
    assert np.asarray(ds.hist).tolist() == [h0, h1]
    ds = dr.reset(ds, jnp.asarray([True, False]))
    assert not np.asarray(ds.keys)[0].any()
    assert np.asarray(ds.hist)[0].tolist() == [-1]
    assert np.asarray(ds.keys)[1].tolist() == k1   # untouched


# --- the accept rule (sampling.spec_verify) ---------------------------------

def test_greedy_verify_emits_only_argmax_tokens():
    """A1 at the unit level: every token spec_verify emits IS the argmax
    of its verify logits, and n_acc counts exactly the leading drafts that
    match the previous position's argmax — so no draft the sequential
    greedy loop would not have produced can ever be emitted."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 5, 32)), jnp.float32)
    t = np.argmax(np.asarray(logits), axis=-1)
    drafts = np.where(rng.random((4, 4)) < 0.5, t[:, :4],
                      rng.integers(0, 32, (4, 4))).astype(np.int32)
    keys = jnp.zeros((4, 2), jnp.uint32)
    out, n_acc, keys2 = smp.spec_verify(logits, jnp.asarray(drafts), keys,
                                        smp.SamplingConfig())
    assert np.array_equal(np.asarray(out), t)      # argmax everywhere
    assert np.array_equal(np.asarray(keys2), np.asarray(keys))  # no RNG
    for b in range(4):
        n = 0
        while n < 4 and drafts[b, n] == t[b, n]:
            n += 1
        assert int(n_acc[b]) == n


def test_stochastic_verify_edge_probabilities():
    """Rejection sampling edges: a draft carrying ~all probability mass is
    always accepted; a draft with zero mass is never accepted and never
    re-emitted by the residual draw."""
    B, L, V = 3, 4, 16
    sure = np.full((B, L, V), -30.0, np.float32)
    sure[..., 7] = 30.0                          # p(7) ~ 1 everywhere
    drafts = jnp.full((B, L - 1), 7, jnp.int32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(B))
    sc = smp.SamplingConfig(method="temperature", temperature=1.0)
    out, n_acc, _ = smp.spec_verify(jnp.asarray(sure), drafts, keys, sc)
    assert np.all(np.asarray(n_acc) == L - 1)
    assert np.all(np.asarray(out) == 7)
    # now the draft token has zero mass: never accepted, and the residual
    # categorical (draft masked to -inf) can never return it either
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(B, L, V)).astype(np.float32)
    logits[..., 3] = -np.inf                     # p(3) = 0
    drafts = jnp.full((B, L - 1), 3, jnp.int32)
    out, n_acc, _ = smp.spec_verify(jnp.asarray(logits), drafts, keys, sc)
    assert np.all(np.asarray(n_acc) == 0)
    assert not np.any(np.asarray(out) == 3)


# --- engine-level greedy parity (invariant A1) ------------------------------

def _serve(cfg, params, jobs, **kw):
    """Staggered submissions (each runs to completion before the next) so
    slot reuse, drafter resets and warm prefix admissions all happen."""
    eng = Engine(cfg, params, num_slots=2, max_seq=64,
                 check_invariants=True, **kw)
    outs = []
    for prompt, n in jobs:
        r = eng.submit(prompt, n)
        eng.run()
        assert r.done
        outs.append(r.out_tokens)
    return outs, eng


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_spec_parity_layouts_and_prefix(name):
    """Greedy streams bit-identical across {spec on, off} x {paged, dense}
    x {prefix cache on, off}.  Prompts are repetitive so the n-gram
    drafter reaches real acceptance (otherwise the rollback path would
    never run), and a shared system prefix makes the warm-prefix + spec
    combination actually share pages."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(0)
    sys_p = list(rng.integers(1, cfg.vocab_size, 16))
    jobs = [(sys_p + list(rng.integers(1, cfg.vocab_size, 4)) * 3, 20),
            (sys_p + list(rng.integers(1, cfg.vocab_size, 5)) * 2, 18),
            (sys_p + list(rng.integers(1, cfg.vocab_size, 4)) * 3, 16)]
    base, _ = _serve(cfg, params, jobs, kv_layout="dense")
    accepted = 0
    for kw in ({"kv_layout": "dense"},
               {"kv_layout": "paged", "prefix_cache": True},
               {"kv_layout": "paged", "prefix_cache": False}):
        outs, eng = _serve(cfg, params, jobs, draft_len=4, **kw)
        assert outs == base, kw
        stats = eng.spec_stats()
        assert stats["enabled"] and stats["drafted"] > 0
        accepted += stats["accepted"]
    # identical engines accept identically; at least one window must have
    # accepted a draft or this test never exercised rollback-after-accept
    assert accepted > 0


def test_spec_midwindow_rejection_spans_page_boundary():
    """A draft window that straddles a page boundary and rejects mid-draft
    must roll the partially-written second page back cleanly: the final
    paged KV pool bit-matches a non-speculative engine's pool (rejected
    rows return to exact zeros), with check_invariants re-proving the
    allocator mirror after every rollback round."""
    cfg, params = _setup("gqa")
    ps = cfg.page_size
    # position ps-2 at admission: the first draft window [ps-2 .. ps+2]
    # crosses the page-0/page-1 boundary immediately
    prompt = list(np.random.default_rng(3).integers(1, cfg.vocab_size,
                                                    ps - 2))
    budget = ps + 4

    def engine(**kw):
        eng = Engine(cfg, params, num_slots=1, max_seq=4 * ps,
                     kv_layout="paged", prefix_cache=False,
                     check_invariants=True, **kw)
        r = eng.submit(prompt, budget)
        eng.run()
        assert r.done
        return r.out_tokens, eng

    base, e0 = engine()
    spec, e1 = engine(draft_len=5)
    assert spec == base
    # same grants, same writes, zeroed rejections -> bitwise-equal pools
    # (float KV leaves are zero-init, so a rolled-back row == a never-
    # written row)
    for a, b in zip(jax.tree_util.tree_leaves(e0.caches),
                    jax.tree_util.tree_leaves(e1.caches)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_spec_stop_budget_and_ceiling_inside_window():
    """Termination parity (A3) when the boundary lands mid-window: a stop
    token inside an accepted run, a budget smaller than the window, and a
    max_seq ceiling crossing the window must all cut the stream exactly
    where sequential decoding would."""
    cfg, params = _setup("gqa")
    prompt = [5, 9, 5, 9, 5, 9, 5, 9]
    ref_eng = Engine(cfg, params, num_slots=1, max_seq=64)
    rr = ref_eng.submit(prompt, 24)
    ref_eng.run()
    ref = rr.out_tokens
    # stop token chosen from mid-stream; speculation must truncate there
    stop = ref[len(ref) // 2]
    want = ref[:ref.index(stop) + 1]
    eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=6,
                 check_invariants=True)
    r = eng.submit(prompt, 24, stop_tokens=(stop,))
    eng.run()
    assert r.out_tokens == want and r.result.finish_reason == "eos"
    # budget not a multiple of the window
    eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=6,
                 check_invariants=True)
    r = eng.submit(prompt, 9)
    eng.run()
    assert r.out_tokens == ref[:9] and r.result.finish_reason == "budget"
    # max_seq ceiling: ask for more than fits; clamped at submit, finishes
    # with reason "max_seq", stream still bit-matches the reference
    eng = Engine(cfg, params, num_slots=1, max_seq=24, draft_len=6,
                 check_invariants=True)
    r = eng.submit(prompt, 100)
    eng.run()
    assert r.out_tokens == ref[:24 - len(prompt)]
    assert r.result.finish_reason == "max_seq"


def test_recurrent_arch_opts_out_silently():
    """Recurrent-hybrid state cannot rewind a rejected draft: requesting
    speculation must not fail — it is silently disabled and the streams
    are identical to a spec-less engine."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(np.random.default_rng(0).integers(1, cfg.vocab_size, 6))

    def serve(**kw):
        eng = Engine(cfg, params, num_slots=1, max_seq=32, **kw)
        r = eng.submit(prompt, 8)
        eng.run()
        return r.out_tokens, eng

    base, _ = serve()
    spec, eng = serve(draft_len=4)
    assert spec == base
    assert eng.draft_len == 0
    st = eng.spec_stats()
    assert not st["enabled"] and st["drafted"] == 0


def test_spec_stochastic_streams_terminate_and_count():
    """The rejection-sampling path emits exactly the asked number of
    tokens and the drafted/accepted counters stay coherent (accepted <=
    drafted; per-request counters sum to the engine totals).  A request's
    stochastic speculative stream is keyed by its seed alone, so it
    reproduces across engines and co-batched traffic."""
    cfg, params = _setup("gqa")
    prompt = [7, 3, 7, 3, 7, 3]
    eng = Engine(cfg, params, num_slots=2, max_seq=64, draft_len=4,
                 sampling="top_k", top_k=8, temperature=0.8,
                 check_invariants=True)
    rs = [eng.submit(prompt, 15, seed=s) for s in (1, 2, 3)]
    results = eng.run()
    assert len(results) == 3
    for res in results:
        assert len(res.tokens) == 15
        assert 0 <= res.accepted_tokens <= res.drafted_tokens
    st = eng.spec_stats()
    assert st["drafted"] == sum(r.drafted_tokens for r in results)
    assert st["accepted"] == sum(r.accepted_tokens for r in results)
    # reproducibility: same seed -> same stochastic speculative stream,
    # alone in a fresh engine vs co-batched above
    eng2 = Engine(cfg, params, num_slots=2, max_seq=64, draft_len=4,
                  sampling="top_k", top_k=8, temperature=0.8)
    r2 = eng2.submit(prompt, 15, seed=2)
    eng2.run()
    assert r2.result.tokens == rs[1].result.tokens
