"""Speculative decoding (runtime/speculate.py + sampling.spec_verify
+ the engine's speculative tick), over BOTH drafters.

The load-bearing contract is invariant A1: under greedy sampling the
emitted streams are bit-identical to non-speculative decoding — whatever
the drafter proposes, however many drafts get rejected, wherever the
rejection lands relative to a page boundary.  This file proves it across
{spec on, off} x {paged, dense} x {prefix cache on, off} on the gqa, mla
and int8-KV cache architectures, with `check_invariants=True` so every
speculative rollback round also re-proves the HostPool mirror == device
allocator equality — and parametrizes the whole engine-level suite over
both `drafter="ngram"` and `drafter="model"` (the 2-bit BRAMAC draft
model), since the engine's tick/admit never inspect which drafter is
plugged in.  The n-gram drafter is property-tested against a pure-Python
replay (invariant A5: the device table is deterministic,
last-write-wins, keys stored with the `h | 1` validity offset so a
zero-hash context cannot false-hit empty buckets); the model drafter's
private draft KV cache is property-tested against a fresh replay of the
verified stream (invariant A6); and the accept rule is unit-tested
directly on both the greedy and rejection-sampling paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import get_config
from repro.models import model as M
from repro.runtime import speculate as spc
from repro.runtime import sampling as smp
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")

ARCHS = {
    "gqa": ("granite-8b", {}),
    "mla": ("minicpm3-4b", {}),
    "int8kv": ("granite-8b", {"quant_kv": True}),
}

_CACHE = {}


def _setup(name):
    if name not in _CACHE:
        arch, over = ARCHS[name]
        cfg = get_config(arch, smoke=True)
        if over:
            cfg = cfg.replace(**over)
        _CACHE[name] = (cfg, M.init_params(cfg, jax.random.PRNGKey(0)))
    return _CACHE[name]


# --- drafter vs pure-Python reference (invariant A5) ------------------------

def _ref_fnv(ctx):
    h = spc.FNV_OFFSET
    for t in ctx:
        h = ((h ^ (int(t) + 1)) * spc.FNV_PRIME) & 0xFFFFFFFF
    return h


def _ref_replay(tokens, ngram, table):
    """Reference table build: feed tokens in order, last write wins.
    Keys carry the `h | 1` validity offset so a zero hash can never
    equal the empty-bucket sentinel 0."""
    keys = [0] * table
    nexts = [0] * table
    hist = [-1] * (ngram - 1)
    for t in tokens:
        h = _ref_fnv(hist)
        idx = h % table
        keys[idx] = h | 1
        nexts[idx] = int(t)
        hist = hist[1:] + [int(t)]
    return keys, nexts, hist


def _ref_propose(keys, nexts, hist, table, draft_len):
    hist = list(hist)
    out = []
    for _ in range(draft_len):
        h = _ref_fnv(hist)
        idx = h % table
        g = nexts[idx] if keys[idx] == (h | 1) else hist[-1]
        out.append(g)
        hist = hist[1:] + [g]
    return out


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(1, 40),
       ngram=st.integers(2, 4),
       table=st.sampled_from([8, 64]))
def test_ngram_table_matches_reference_replay(seed, n, ngram, table):
    """Device observe/propose bit-match the pure-Python replay — including
    bucket collisions (table=8 forces them), so the scan's last-write-wins
    ordering is what actually lands (a duplicate-index scatter would be
    nondeterministic here)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 50, size=n)
    dr = spc.NGramDrafter(ngram=ngram, table=table)
    ds = dr.init_state(1)
    ds = dr.observe(ds, jnp.asarray(toks[None], jnp.int32),
                    jnp.ones((1, n), bool))
    keys, nexts, hist = _ref_replay(toks, ngram, table)
    assert np.asarray(ds.keys)[0].tolist() == keys
    assert np.asarray(ds.nexts)[0].tolist() == nexts
    assert np.asarray(ds.hist)[0].tolist() == hist
    drafts = np.asarray(dr.propose(ds, 4))[0].tolist()
    assert drafts == _ref_propose(keys, nexts, hist, table, 4)


def test_ngram_observe_mask_and_reset():
    """Masked positions must not insert or shift history, and reset must
    clear exactly the masked slots."""
    dr = spc.NGramDrafter(ngram=2, table=16)
    ds = dr.observe(dr.init_state(2),
                    jnp.asarray([[3, 4, 5], [3, 9, 5]], jnp.int32),
                    jnp.asarray([[True, True, True],
                                 [True, False, True]]))
    # slot 1 skipped token 9: its table equals replaying [3, 5]
    k0, n0, h0 = _ref_replay([3, 4, 5], 2, 16)
    k1, n1, h1 = _ref_replay([3, 5], 2, 16)
    assert np.asarray(ds.keys)[0].tolist() == k0
    assert np.asarray(ds.keys)[1].tolist() == k1
    assert np.asarray(ds.nexts)[1].tolist() == n1
    assert np.asarray(ds.hist).tolist() == [h0, h1]
    ds = dr.reset(ds, jnp.asarray([True, False]))
    assert not np.asarray(ds.keys)[0].any()
    assert np.asarray(ds.hist)[0].tolist() == [-1]
    assert np.asarray(ds.keys)[1].tolist() == k1   # untouched


# (t + 1) wraps to the FNV-1a offset basis in uint32, so the one-token
# context [ZERO_TOK] hashes to exactly 0 — the empty-bucket sentinel
ZERO_TOK = -2128831036


def test_ngram_zero_hash_context_misses_empty_buckets():
    """Regression: a context hashing to 0 used to false-hit every EMPTY
    bucket (keys init to 0, lookup was `keys[idx] == h`) and draft token
    0.  With the `h | 1` validity offset the empty table misses and the
    repeat-last fallback applies; a real insert under the zero hash still
    round-trips."""
    h = spc.ngram_hash(jnp.asarray([[ZERO_TOK]], jnp.int32))
    assert int(np.asarray(h)[0]) == 0          # the crafted collision
    dr = spc.NGramDrafter(ngram=2, table=16)
    ds = dr.init_state(1)._replace(
        hist=jnp.asarray([[ZERO_TOK]], jnp.int32))
    drafts = np.asarray(dr.propose(ds, 3))[0]
    # empty table -> repeat-last fallback, never the phantom token 0
    assert drafts.tolist() == [ZERO_TOK] * 3
    # insert under the zero-hash context, then look it up
    ds = dr.observe(ds, jnp.asarray([[42]], jnp.int32),
                    jnp.ones((1, 1), bool))
    assert np.asarray(ds.keys)[0, 0] == 1      # stored as 0 | 1
    ds = ds._replace(hist=jnp.asarray([[ZERO_TOK]], jnp.int32))
    assert np.asarray(dr.propose(ds, 1))[0].tolist() == [42]


# --- the accept rule (sampling.spec_verify) ---------------------------------

def test_greedy_verify_emits_only_argmax_tokens():
    """A1 at the unit level: every token spec_verify emits IS the argmax
    of its verify logits, and n_acc counts exactly the leading drafts that
    match the previous position's argmax — so no draft the sequential
    greedy loop would not have produced can ever be emitted."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 5, 32)), jnp.float32)
    t = np.argmax(np.asarray(logits), axis=-1)
    drafts = np.where(rng.random((4, 4)) < 0.5, t[:, :4],
                      rng.integers(0, 32, (4, 4))).astype(np.int32)
    keys = jnp.zeros((4, 2), jnp.uint32)
    out, n_acc, keys2 = smp.spec_verify(logits, jnp.asarray(drafts), keys,
                                        smp.SamplingConfig())
    assert np.array_equal(np.asarray(out), t)      # argmax everywhere
    assert np.array_equal(np.asarray(keys2), np.asarray(keys))  # no RNG
    for b in range(4):
        n = 0
        while n < 4 and drafts[b, n] == t[b, n]:
            n += 1
        assert int(n_acc[b]) == n


def test_stochastic_verify_edge_probabilities():
    """Rejection sampling edges: a draft carrying ~all probability mass is
    always accepted; a draft with zero mass is never accepted and never
    re-emitted by the residual draw."""
    B, L, V = 3, 4, 16
    sure = np.full((B, L, V), -30.0, np.float32)
    sure[..., 7] = 30.0                          # p(7) ~ 1 everywhere
    drafts = jnp.full((B, L - 1), 7, jnp.int32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(B))
    sc = smp.SamplingConfig(method="temperature", temperature=1.0)
    out, n_acc, _ = smp.spec_verify(jnp.asarray(sure), drafts, keys, sc)
    assert np.all(np.asarray(n_acc) == L - 1)
    assert np.all(np.asarray(out) == 7)
    # now the draft token has zero mass: never accepted, and the residual
    # categorical (draft masked to -inf) can never return it either
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(B, L, V)).astype(np.float32)
    logits[..., 3] = -np.inf                     # p(3) = 0
    drafts = jnp.full((B, L - 1), 3, jnp.int32)
    out, n_acc, _ = smp.spec_verify(jnp.asarray(logits), drafts, keys, sc)
    assert np.all(np.asarray(n_acc) == 0)
    assert not np.any(np.asarray(out) == 3)


# --- engine-level greedy parity (invariant A1) ------------------------------

def _serve(cfg, params, jobs, **kw):
    """Staggered submissions (each runs to completion before the next) so
    slot reuse, drafter resets and warm prefix admissions all happen."""
    eng = Engine(cfg, params, num_slots=2, max_seq=64,
                 check_invariants=True, **kw)
    outs = []
    for prompt, n in jobs:
        r = eng.submit(prompt, n)
        eng.run()
        assert r.done
        outs.append(r.out_tokens)
    return outs, eng


@pytest.mark.parametrize("drafter", ("ngram", "model"))
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_spec_parity_layouts_and_prefix(name, drafter):
    """Greedy streams bit-identical across {spec on, off} x {paged, dense}
    x {prefix cache on, off}, for BOTH drafters (the Drafter-conformance
    half of the harness: the engine never inspects which drafter is
    plugged in, and A1 holds whatever it proposes).  Prompts are
    repetitive so the n-gram drafter reaches real acceptance (otherwise
    the rollback path would never run), and a shared system prefix makes
    the warm-prefix + spec combination actually share pages (the model
    drafter silently opts out of the prefix cache but must stream
    identically there too)."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(0)
    sys_p = list(rng.integers(1, cfg.vocab_size, 16))
    jobs = [(sys_p + list(rng.integers(1, cfg.vocab_size, 4)) * 3, 20),
            (sys_p + list(rng.integers(1, cfg.vocab_size, 5)) * 2, 18),
            (sys_p + list(rng.integers(1, cfg.vocab_size, 4)) * 3, 16)]
    base, _ = _serve(cfg, params, jobs, kv_layout="dense")
    accepted = 0
    for kw in ({"kv_layout": "dense"},
               {"kv_layout": "paged", "prefix_cache": True},
               {"kv_layout": "paged", "prefix_cache": False}):
        outs, eng = _serve(cfg, params, jobs, draft_len=4, drafter=drafter,
                           **kw)
        assert outs == base, kw
        stats = eng.spec_stats()
        assert stats["enabled"] and stats["drafted"] > 0
        assert stats["drafter"] == drafter
        accepted += stats["accepted"]
    # identical engines accept identically; for the n-gram drafter on
    # these repetitive prompts at least one window must have accepted a
    # draft or this test never exercised rollback-after-accept (the
    # 2-bit model drafter's acceptance on random tiny weights is not
    # guaranteed — its separation is proven on the structured stream
    # in test_model_drafter_beats_ngram_on_structured_stream)
    if drafter == "ngram":
        assert accepted > 0


@pytest.mark.parametrize("drafter", ("ngram", "model"))
def test_spec_midwindow_rejection_spans_page_boundary(drafter):
    """A draft window that straddles a page boundary and rejects mid-draft
    must roll the partially-written second page back cleanly: the final
    paged KV pool bit-matches a non-speculative engine's pool (rejected
    rows return to exact zeros), with check_invariants re-proving the
    allocator mirror after every rollback round."""
    cfg, params = _setup("gqa")
    ps = cfg.page_size
    # position ps-2 at admission: the first draft window [ps-2 .. ps+2]
    # crosses the page-0/page-1 boundary immediately
    prompt = list(np.random.default_rng(3).integers(1, cfg.vocab_size,
                                                    ps - 2))
    budget = ps + 4

    def engine(**kw):
        eng = Engine(cfg, params, num_slots=1, max_seq=4 * ps,
                     kv_layout="paged", prefix_cache=False,
                     check_invariants=True, **kw)
        r = eng.submit(prompt, budget)
        eng.run()
        assert r.done
        return r.out_tokens, eng

    base, e0 = engine()
    spec, e1 = engine(draft_len=5, drafter=drafter)
    assert spec == base
    # same grants, same writes, zeroed rejections -> bitwise-equal pools
    # (float KV leaves are zero-init, so a rolled-back row == a never-
    # written row)
    for a, b in zip(jax.tree_util.tree_leaves(e0.caches),
                    jax.tree_util.tree_leaves(e1.caches)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("drafter", ("ngram", "model"))
def test_spec_stop_budget_and_ceiling_inside_window(drafter):
    """Termination parity (A3) when the boundary lands mid-window: a stop
    token inside an accepted run, a budget smaller than the window, and a
    max_seq ceiling crossing the window must all cut the stream exactly
    where sequential decoding would — under either drafter."""
    cfg, params = _setup("gqa")
    prompt = [5, 9, 5, 9, 5, 9, 5, 9]
    ref_eng = Engine(cfg, params, num_slots=1, max_seq=64)
    rr = ref_eng.submit(prompt, 24)
    ref_eng.run()
    ref = rr.out_tokens
    # stop token chosen from mid-stream; speculation must truncate there
    stop = ref[len(ref) // 2]
    want = ref[:ref.index(stop) + 1]
    eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=6,
                 drafter=drafter, check_invariants=True)
    r = eng.submit(prompt, 24, stop_tokens=(stop,))
    eng.run()
    assert r.out_tokens == want and r.result.finish_reason == "eos"
    # budget not a multiple of the window
    eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=6,
                 drafter=drafter, check_invariants=True)
    r = eng.submit(prompt, 9)
    eng.run()
    assert r.out_tokens == ref[:9] and r.result.finish_reason == "budget"
    # max_seq ceiling: ask for more than fits; clamped at submit, finishes
    # with reason "max_seq", stream still bit-matches the reference
    eng = Engine(cfg, params, num_slots=1, max_seq=24, draft_len=6,
                 drafter=drafter, check_invariants=True)
    r = eng.submit(prompt, 100)
    eng.run()
    assert r.out_tokens == ref[:24 - len(prompt)]
    assert r.result.finish_reason == "max_seq"


def test_recurrent_arch_opts_out_silently():
    """Recurrent-hybrid state cannot rewind a rejected draft: requesting
    speculation must not fail — it is silently disabled and the streams
    are identical to a spec-less engine."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(np.random.default_rng(0).integers(1, cfg.vocab_size, 6))

    def serve(**kw):
        eng = Engine(cfg, params, num_slots=1, max_seq=32, **kw)
        r = eng.submit(prompt, 8)
        eng.run()
        return r.out_tokens, eng

    base, _ = serve()
    spec, eng = serve(draft_len=4)
    assert spec == base
    assert eng.draft_len == 0
    st = eng.spec_stats()
    assert not st["enabled"] and st["drafted"] == 0


@pytest.mark.parametrize("drafter", ("ngram", "model"))
def test_spec_stochastic_streams_terminate_and_count(drafter):
    """The rejection-sampling path emits exactly the asked number of
    tokens and the drafted/accepted counters stay coherent (accepted <=
    drafted; per-request counters sum to the engine totals).  A request's
    stochastic speculative stream is keyed by its seed alone, so it
    reproduces across engines and co-batched traffic."""
    cfg, params = _setup("gqa")
    prompt = [7, 3, 7, 3, 7, 3]
    eng = Engine(cfg, params, num_slots=2, max_seq=64, draft_len=4,
                 drafter=drafter, sampling="top_k", top_k=8,
                 temperature=0.8, check_invariants=True)
    rs = [eng.submit(prompt, 15, seed=s) for s in (1, 2, 3)]
    results = eng.run()
    assert len(results) == 3
    for res in results:
        assert len(res.tokens) == 15
        assert 0 <= res.accepted_tokens <= res.drafted_tokens
    st = eng.spec_stats()
    assert st["drafted"] == sum(r.drafted_tokens for r in results)
    assert st["accepted"] == sum(r.accepted_tokens for r in results)
    # reproducibility: same seed -> same stochastic speculative stream,
    # alone in a fresh engine vs co-batched above
    eng2 = Engine(cfg, params, num_slots=2, max_seq=64, draft_len=4,
                  drafter=drafter, sampling="top_k", top_k=8,
                  temperature=0.8)
    r2 = eng2.submit(prompt, 15, seed=2)
    eng2.run()
    assert r2.result.tokens == rs[1].result.tokens


# --- the model drafter: conformance, invariant A6, acceptance ---------------

_QD = {}


def _qdrafter(max_seq=64):
    """Module-cached 2-bit drafter over the gqa smoke arch (requantizing
    the tree per example would dominate the property tests)."""
    if max_seq not in _QD:
        cfg, params = _setup("gqa")
        _QD[max_seq] = spc.QuantDrafter.build(cfg, params, max_seq=max_seq,
                                              bits=2, draft_layers=None)
    return _QD[max_seq]


@pytest.mark.parametrize("kind", ("ngram", "model"))
def test_drafter_reset_equals_never_observed(kind):
    """Drafter-conformance harness, shared by both implementations:
    propose returns (S, draft_len) i32 and is read-only, and resetting a
    slot leaves state bit-equal to never having observed that slot at
    all — the property the engine's admission relies on for slot reuse."""
    if kind == "ngram":
        dr = spc.NGramDrafter(ngram=2, table=32)
    else:
        dr = _qdrafter(32)
    toks = jnp.asarray([[5, 6, 7, 8, 9], [11, 12, 13, 14, 15]], jnp.int32)
    mask = jnp.asarray([[1, 1, 1, 1, 1], [1, 1, 1, 0, 0]], bool)
    ds = dr.observe(dr.init_state(2), toks, mask)
    g = dr.propose(ds, 4)
    assert g.shape == (2, 4) and g.dtype == jnp.int32
    assert np.array_equal(np.asarray(g), np.asarray(dr.propose(ds, 4)))
    ds_r = dr.reset(ds, jnp.asarray([True, False]))
    fresh = dr.observe(dr.init_state(2), toks,
                       mask & jnp.asarray([[False], [True]]))
    for a, b in zip(jax.tree_util.tree_leaves(ds_r),
                    jax.tree_util.tree_leaves(fresh)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 20),
       cut=st.integers(1, 19))
def test_a6_chunked_observe_equals_one_shot_replay(seed, n, cut):
    """A6 at the drafter level: observing a verified stream in two
    arbitrary chunks leaves the draft cache identical to observing it in
    one shot — the cache is a pure function of the verified stream, not
    of the tick/admission chunking that fed it."""
    dr = _qdrafter()
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 50, size=n)
    cut = min(cut, n - 1)
    one = dr.observe(dr.init_state(1), jnp.asarray(toks[None], jnp.int32),
                     jnp.ones((1, n), bool))
    two = dr.init_state(1)
    for piece in (toks[:cut], toks[cut:]):
        two = dr.observe(two, jnp.asarray(piece[None], jnp.int32),
                         jnp.ones((1, len(piece)), bool))
    assert int(two.n_stream[0]) == n and int(two.last[0]) == toks[-1]
    for a, b in zip(jax.tree_util.tree_leaves(one.caches),
                    jax.tree_util.tree_leaves(two.caches)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("layout", ("paged", "dense"))
def test_a6_engine_draft_cache_equals_stream_replay(layout):
    """A6 end-to-end: after serving a request (random weights, so verify
    rejects most windows mid-draft), the slot's draft cache bit-equals a
    fresh replay of prompt + emitted tokens — rejected draft rows left no
    residue, and the bookkeeping (n_stream, last) tracks the verified
    stream exactly."""
    cfg, params = _setup("gqa")
    eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=4,
                 drafter="model", kv_layout=layout)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    r = eng.submit(prompt, 12)
    eng.run()
    assert r.done
    stream = list(prompt) + list(r.out_tokens)
    dr = eng.drafter
    fresh = dr.observe(dr.init_state(1), jnp.asarray([stream], jnp.int32),
                       jnp.ones((1, len(stream)), bool))
    assert int(eng.state.draft.n_stream[0]) == len(stream)
    assert int(eng.state.draft.last[0]) == stream[-1]
    for a, b in zip(jax.tree_util.tree_leaves(eng.state.draft.caches),
                    jax.tree_util.tree_leaves(fresh.caches)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def _structured_params(cfg):
    """Integer-exact toy weights whose greedy stream is structured but
    non-repetitive: layers all zero (residual passes the embedding
    through), embedding[t] = onehot(t % d_model), unembed[i, (i+1) %
    d_model] = 1 — so the model deterministically continues t -> t+1
    (mod d_model).  Every value survives 2-bit quantization exactly
    ({0, 1} weights; one-hot activations), so the 2-bit draft model
    agrees with the float verify path bit-for-bit while the n-gram
    drafter never sees a context twice."""
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(jnp.zeros_like, params)
    D, V = cfg.d_model, cfg.vocab_size
    emb = jnp.zeros((V, D)).at[jnp.arange(V), jnp.arange(V) % D].set(1.0)
    unemb = jnp.zeros((D, V)).at[jnp.arange(D),
                                 (jnp.arange(D) + 1) % D].set(1.0)
    params["embed"]["embedding"] = emb.astype(cfg.compute_dtype)
    params["embed"]["unembed"] = unemb.astype(cfg.compute_dtype)
    params["final_norm"] = jax.tree_util.tree_map(
        jnp.ones_like, params["final_norm"])
    return params


def test_model_drafter_beats_ngram_on_structured_stream():
    """The model drafter's reason to exist: on a structured but
    NON-repetitive stream (t -> t+1, every n-gram context fresh) the
    n-gram drafter accepts nothing while the 2-bit draft model accepts
    essentially every window — fewer ticks for the same bit-identical
    stream."""
    cfg, _ = _setup("gqa")
    params = _structured_params(cfg)
    prompt, n = [1, 2, 3], 20
    expect = [(prompt[-1] + 1 + i) % cfg.d_model for i in range(n)]
    stats, ticks = {}, {}
    for drafter in ("ngram", "model"):
        eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=4,
                     drafter=drafter)
        r = eng.submit(prompt, n)
        eng.run()
        assert r.out_tokens == expect, drafter    # A1 under both drafters
        stats[drafter] = eng.spec_stats()
        ticks[drafter] = eng.n_ticks
    assert stats["ngram"]["accepted"] == 0
    assert stats["model"]["accepted"] > 0
    # every model draft inside the budget is exact; at most the final
    # clamped window leaves drafts unconsumed
    assert stats["model"]["accepted"] >= stats["model"]["drafted"] - 4
    assert ticks["model"] < ticks["ngram"]


@pytest.mark.parametrize("drafter", ("ngram", "model"))
def test_spec_stats_survive_abort(drafter):
    """Satellite contract: spec_stats reports the drafter identity, and
    an aborted request's in-flight drafted/accepted split folds into the
    engine totals instead of vanishing with the vacated slot."""
    cfg, params = _setup("gqa")
    eng = Engine(cfg, params, num_slots=1, max_seq=64, draft_len=4,
                 drafter=drafter)
    r = eng.submit([5, 9, 5, 9, 5, 9], 40)
    for _ in range(4):
        eng.step()
    st = eng.spec_stats()
    assert st["drafter"] == drafter and st["drafted"] > 0
    assert not r.done
    assert eng.abort(r)
    st2 = eng.spec_stats()
    assert st2["drafted"] == st["drafted"]
    assert st2["accepted"] == st["accepted"]
    assert r.result.finish_reason == "aborted"
    # the totals now live on the engine, not the vacated slot
    assert eng.tokens_drafted == st["drafted"]
