"""Per-architecture smoke tests: reduced config, one forward + train-grad
step + a prefill/decode round-trip on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.audio_frontend:
        batch["frame_embeds"] = jax.random.normal(
            ks[0], (B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision_tokens, cfg.d_model), jnp.float32)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param, smoke=True)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    return request.param, cfg, params, batch


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, params, batch = arch_setup
    logits, aux, _ = jax.jit(
        lambda p, b: M.forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(float(aux))


def test_train_grad_step(arch_setup):
    arch, cfg, params, batch = arch_setup

    @jax.jit
    def step(p, b):
        (loss, parts), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, b, cfg), has_aux=True)(p)
        return loss, g

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no grads"
    for leaf in leaves:
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    # at least one non-zero gradient per model
    total = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
                for l in leaves)
    assert total > 0


def test_prefill_decode_roundtrip(arch_setup):
    arch, cfg, params, batch = arch_setup
    max_seq = S + 4
    caches = M.init_cache(cfg, B, max_seq)
    prefill_batch = dict(batch)
    prefill_batch.pop("labels")
    logits, caches = jax.jit(
        lambda p, b, c: M.prefill(p, b, cfg, c))(params, prefill_batch, caches)
    assert logits.shape == (B, cfg.vocab_size)
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    logits2, caches = jax.jit(
        lambda p, t, c, q: M.decode_step(p, t, cfg, c, q))(
            params, next_tok, caches, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


def test_decode_matches_full_forward():
    """Decode-path equivalence: token-by-token == full forward (granite)."""
    cfg = get_config("granite-8b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0,
                                cfg.vocab_size)
    full_logits, _, _ = M.forward(params, {"tokens": tokens}, cfg)

    caches = M.init_cache(cfg, B, 8)
    prefix = {"tokens": tokens[:, :4]}
    _, caches = M.prefill(params, prefix, cfg, caches)
    outs = []
    for t in range(4, 8):
        pos = jnp.full((B,), t, jnp.int32)
        lg, caches = M.decode_step(params, tokens[:, t:t + 1], cfg, caches,
                                   pos)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full_logits[:, 4:8]),
                               rtol=2e-3, atol=2e-3)
