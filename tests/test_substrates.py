"""Substrate tests: optimizer (+int8 states), checkpoint (+elastic restore),
data pipeline determinism, fault-tolerant trainer, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.serve import Engine
from repro.runtime.trainer import Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


# --- optimizer --------------------------------------------------------------

def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (64, 32)),
            "b": {"w": jax.random.normal(k2, (32,)),
                  "s": jnp.ones((7, 3))}}


@pytest.mark.parametrize("quantized", [False, True])
def test_adamw_descends(quantized):
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                            quantize_state=quantized)
    params = _toy_params(jax.random.PRNGKey(0))
    target = _toy_params(jax.random.PRNGKey(1))
    state = adamw.init(params, cfg)

    def loss(p):
        return sum(jnp.sum((x - t) ** 2) for x, t in
                   zip(jax.tree_util.tree_leaves(p),
                       jax.tree_util.tree_leaves(target)))

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, metrics = adamw.apply(params, state, grads, cfg)
    assert float(loss(params)) < 0.05 * l0
    assert np.isfinite(metrics["grad_norm"])


def test_int8_state_roundtrip_precision():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q = adamw._q8(x)
    err = np.abs(np.asarray(adamw._dq8(q) - x))
    blockmax = np.abs(np.asarray(x)).max()
    assert err.max() <= blockmax / 127 + 1e-6


def test_quantized_state_memory_is_smaller():
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    sq = adamw.init(params, adamw.AdamWConfig(quantize_state=True))
    sf = adamw.init(params, adamw.AdamWConfig(quantize_state=False))
    bytes_q = sum(l.nbytes for l in jax.tree_util.tree_leaves(sq))
    bytes_f = sum(l.nbytes for l in jax.tree_util.tree_leaves(sf))
    assert bytes_q < 0.4 * bytes_f


# --- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": _toy_params(jax.random.PRNGKey(2)),
            "step": jnp.int32(7)}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    back = ckpt.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_prune(tmp_path):
    tree = {"x": jnp.arange(10)}
    for s in (1, 2, 3, 4):
        t = ckpt.save(str(tmp_path), s, tree, blocking=False)
        t.join()
    ckpt.prune(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


@pytest.mark.multidevice
def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a different mesh topology (8 → 4 virtual devices)."""
    from conftest import run_multidevice
    out = run_multidevice(f"""
from repro.checkpoint import ckpt

tree = {{"w": jnp.arange(64.).reshape(8, 8)}}
mesh8 = Mesh(np.array(jax.devices()[:8]).reshape(8), ("data",))
sh8 = {{"w": NamedSharding(mesh8, P("data"))}}
tree = jax.tree_util.tree_map(jax.device_put, tree, sh8)
ckpt.save({str(tmp_path)!r}, 1, tree)

mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
sh4 = {{"w": NamedSharding(mesh4, P("data"))}}
back = ckpt.restore({str(tmp_path)!r}, 1, tree, shardings=sh4)
assert back["w"].sharding.mesh.shape["data"] == 4
np.testing.assert_array_equal(np.asarray(back["w"]),
                              np.arange(64.).reshape(8, 8))
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


# --- data pipeline ----------------------------------------------------------

def test_pipeline_deterministic_and_learnable():
    cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=4, seed=3)
    pipe = SyntheticLM(cfg)
    a = pipe.batch_np(10)
    b = pipe.batch_np(10)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch_np(11)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


# --- fault-tolerant trainer -------------------------------------------------

def test_trainer_failure_recovery(tmp_path):
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                         async_ckpt=False,
                         opt=adamw.AdamWConfig(lr=1e-3))
    pipe = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=16, global_batch=2))

    crashed = {"done": False}

    def failure_hook(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    trainer = Trainer(cfg, tcfg, params)
    history = trainer.train(pipe, num_steps=8, failure_hook=failure_hook)
    assert trainer.step == 8
    assert crashed["done"]
    # steps 4..5 replayed after rollback to checkpoint at 4
    assert len(history) >= 8
    assert all(np.isfinite(h["loss"]) for h in history)
    assert ckpt.latest_step(str(tmp_path)) == 8


def test_trainer_straggler_watchdog(tmp_path):
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                         straggler_factor=2.0, async_ckpt=False)
    trainer = Trainer(cfg, tcfg, params)
    pipe = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=16, global_batch=2))
    import time as _t
    for step in range(6):
        batch = pipe.batch(step)
        if step == 5:
            _t.sleep(1.0)          # simulate a slow host before the step
            t0 = _t.perf_counter()
            trainer.run_step(batch)
            continue
        trainer.run_step(batch)
    # watchdog itself is exercised via the EWMA bookkeeping
    assert trainer._ewma is not None


# --- serving engine ---------------------------------------------------------

def test_engine_serves_batched_requests():
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=n), 5)
            for n in (7, 12, 9)]
    eng.run()
    for r in reqs:
        assert r.done
        assert len(r.out_tokens) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_engine_serves_hybrid_arch():
    """Continuous batching with mixed recurrent+attention+MoE state (jamba):
    the admission merge must handle KV caches, mamba (h, conv) and MoE
    together, and recurrent archs must prefill token-by-token (their state
    cannot skip padding)."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=2, max_seq=48)
    assert eng.prefill_chunk == 1    # token-by-token prefill for SSM archs
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=n), 3)
            for n in (5, 9, 6)]
    eng.run()
    assert all(r.done and len(r.out_tokens) == 3 for r in reqs)


def test_engine_matches_unbatched_decode():
    """Engine output == straight prefill+decode for a single request."""
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(1, 9, dtype=np.int32)

    eng = Engine(cfg, params, num_slots=2, max_seq=32)
    r = eng.submit(prompt, 4)
    eng.run()

    caches = M.init_cache(cfg, 1, 32)
    _, caches = M.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                          cfg, caches)
    # recompute the first token from the last prompt logits
    logits, _, caches2 = M.forward(
        params, {"tokens": jnp.asarray(prompt)[None]}, cfg,
        caches=M.init_cache(cfg, 1, 32),
        cache_pos=jnp.zeros((1,), jnp.int32))
    toks = [int(jnp.argmax(logits[0, -1]))]
    caches = caches2
    for i in range(3):
        pos = jnp.asarray([len(prompt) + i], jnp.int32)
        lg, caches = M.decode_step(params, jnp.asarray([[toks[-1]]]), cfg,
                                   caches, pos)
        toks.append(int(jnp.argmax(lg[0])))
    assert r.out_tokens == toks
