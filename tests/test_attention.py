"""Attention-mixer unit tests: chunked == unchunked, GQA grouping, caches."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.models import attention as A

jax.config.update("jax_platform_name", "cpu")


def _qkv(key, B, S, H, Hkv, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    return q, k, v


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), group=st.sampled_from([1, 2, 4]))
def test_gqa_grouping_matches_repeated_kv(seed, group):
    """GQA == MHA with kv heads repeated `group` times."""
    B, S, Hkv, hd = 2, 8, 2, 16
    H = Hkv * group
    q, k, v = _qkv(jax.random.PRNGKey(seed), B, S, H, Hkv, hd)
    got = A.causal_attention(q, k, v)
    k_rep = jnp.repeat(k, group, axis=2)
    v_rep = jnp.repeat(v, group, axis=2)
    want = A.causal_attention(q, k_rep, v_rep)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunked_equals_unchunked():
    """Query-chunked path == single-shot attention."""
    B, H, Hkv, hd = 1, 4, 2, 8
    S = 4 * A.Q_CHUNK if A.Q_CHUNK <= 64 else 0
    old = A.Q_CHUNK
    try:
        A.Q_CHUNK = 16
        q, k, v = _qkv(jax.random.PRNGKey(0), B, 64, H, Hkv, hd)
        chunked = A.causal_attention(q, k, v)
        A.Q_CHUNK = 64
        full = A.causal_attention(q, k, v)
    finally:
        A.Q_CHUNK = old
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_masks_future():
    """Keys beyond pos contribute nothing."""
    B, H, Hkv, hd, S = 2, 2, 2, 8, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd))
    pos = jnp.array([3, 7], jnp.int32)
    out = A.decode_attention(q, k, v, pos)
    # corrupt keys/values beyond each pos — output must not change
    k2 = k.at[0, 4:].set(99.0).at[1, 8:].set(99.0)
    v2 = v.at[0, 4:].set(-99.0).at[1, 8:].set(-99.0)
    out2 = A.decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


def test_cache_update_at_position():
    cache = jnp.zeros((2, 8, 2, 4))
    new = jnp.ones((2, 1, 2, 4))
    pos = jnp.array([2, 5], jnp.int32)
    out = A._update_cache(cache, new, pos)
    assert float(out[0, 2].sum()) == 8.0 and float(out[1, 5].sum()) == 8.0
    assert float(out[0, 5].sum()) == 0.0 and float(out[1, 2].sum()) == 0.0


def test_int8_kv_cache_decode_close_to_fp():
    """quant_kv decode (int8 K, int8 V with scales folded into probs)
    tracks the bf16-cache decode closely, end to end."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)

    outs = {}
    for name, c in (("fp", cfg), ("kv8", cfg.replace(quant_kv=True))):
        caches = M.init_cache(c, 2, 12)
        _, caches = M.prefill(params, {"tokens": tokens}, c, caches)
        pos = jnp.full((2,), 8, jnp.int32)
        logits, _ = M.decode_step(params, tokens[:, :1], c, caches, pos)
        outs[name] = logits
    a, b = outs["fp"].astype(jnp.float32), outs["kv8"].astype(jnp.float32)
    cos = float(jnp.sum(a * b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    assert cos > 0.995, cos
    # int8 cache really is int8
    caches = M.init_cache(cfg.replace(quant_kv=True), 2, 12)
    leaf = caches["pos0"]["k"]
    assert leaf.dtype == jnp.int8


def test_quant_rows_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 4, 32)) * 5
    q, s = A._quant_rows(x)
    err = jnp.abs(q.astype(jnp.float32) * s[..., None] - x)
    assert float(jnp.max(err / s[..., None])) <= 0.5 + 1e-3


def test_rope_relative_property():
    """RoPE: q·k depends only on relative offset."""
    from repro.models.layers import apply_rope
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 10_000.0)
        kr = apply_rope(k, jnp.array([[pk]]), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


def test_causal_attention_ragged_tail():
    """Sq not divisible by Q_CHUNK runs the full chunks through the scanned
    body plus one trailing partial chunk — same result as unchunked."""
    B, H, Hkv, hd = 1, 4, 2, 8
    old = A.Q_CHUNK
    try:
        A.Q_CHUNK = 16
        q, k, v = _qkv(jax.random.PRNGKey(2), B, A.Q_CHUNK + 1, H, Hkv, hd)
        ragged = A.causal_attention(q, k, v)
        A.Q_CHUNK = 64
        full = A.causal_attention(q, k, v)
    finally:
        A.Q_CHUNK = old
    assert ragged.shape == full.shape
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_negative_position_never_writes_dense():
    """Regression: a padding row at position -1 used to wrap through
    numpy-style negative indexing into the cache's last row; the write
    masks must drop it."""
    cache = jnp.zeros((2, 8, 2, 4))
    new = jnp.ones((2, 1, 2, 4))
    dv = A.DenseKV(write_mask=jnp.ones((2,), bool), max_seq=8)
    out = A.dense_update(cache, new, jnp.array([[-1], [3]], jnp.int32), dv)
    assert float(jnp.abs(out[0]).sum()) == 0.0      # -1 must not alias row 7
    assert float(jnp.abs(out[1, 3]).sum()) == 8.0   # in-range row still lands


def test_negative_position_never_writes_paged():
    """Regression: position -1 floor-divides to page index -1 (which passes
    `< n_pages`), clips to table entry 0 and wraps its row positive —
    without the lower bound it landed inside a live page."""
    P, ps = 4, 4
    pool = jnp.zeros((P, ps, 2, 4))
    new = jnp.ones((2, 1, 2, 4))
    pv = A.PagedKV(tables=jnp.array([[1, 2], [3, 0]], jnp.int32),
                   n_pages=jnp.array([2, 2], jnp.int32),
                   write_mask=jnp.ones((2,), bool),
                   max_seq=8, page_size=ps)
    out = A.paged_update(pool, new, jnp.array([[-1], [5]], jnp.int32), pv)
    # slot 0's write at -1 must vanish: its pages (1 and 2) stay zero
    assert float(jnp.abs(out[1]).sum()) == 0.0
    assert float(jnp.abs(out[2]).sum()) == 0.0
    # slot 1's in-range write lands in page 0 (table entry 1), row 5%4
    assert float(jnp.abs(out[0, 1]).sum()) == 8.0
