"""Property tests on the model-zoo's structural invariants:

  * chunkwise-parallel mLSTM == step-by-step recurrent mLSTM
  * chunked mamba scan == single-chunk scan == step-by-step decode
  * capacity MoE dispatch == dense all-experts reference when no drops
  * sLSTM sequence == step-by-step decode
  * stack with scan_layers=True == unrolled stack
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models import mamba as mb
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.models import xlstm as xl

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)


def _xl_cfg(chunk):
    return get_config("xlstm-1.3b", smoke=True).replace(chunk_size=chunk)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([2, 4, 8]),
       S=st.sampled_from([8, 16]))
def test_mlstm_chunkwise_equals_recurrent(seed, chunk, S):
    cfg = _xl_cfg(chunk)
    key = jax.random.PRNGKey(seed)
    p = xl.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, cfg.d_model),
                          jnp.float32)
    seq, _ = xl.mlstm_sequence(p, x, cfg)
    rec = xl.mlstm_recurrent_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(rec),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([2, 4, 16]))
def test_mamba_chunked_equals_stepwise(seed, chunk):
    cfg = get_config("jamba-1.5-large-398b", smoke=True).replace(
        chunk_size=chunk)
    key = jax.random.PRNGKey(seed)
    p = mb.init_mamba(key, cfg)
    S = 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, cfg.d_model),
                          jnp.float32)
    seq, (hT, tail) = mb.mamba_sequence(p, x, cfg)

    state = mb.init_mamba_state(cfg, 2, x.dtype)
    outs = []
    for t in range(S):
        o, state = mb.mamba_step(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(state[0]),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_slstm_sequence_equals_stepwise(seed):
    cfg = _xl_cfg(8)
    key = jax.random.PRNGKey(seed)
    p = xl.init_slstm(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          jnp.float32)
    seq, _ = xl.slstm_sequence(p, x, cfg)
    state = xl.init_slstm_state(cfg, 2)
    outs = []
    for t in range(8):
        o, state = xl.slstm_step(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(seq),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), E=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_moe_capacity_dispatch_exact_when_no_drops(seed, E, k):
    cfg = get_config("dbrx-132b", smoke=True).replace(
        num_experts=E, experts_per_token=k)
    key = jax.random.PRNGKey(seed)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          jnp.float32)
    # capacity_factor = E/k guarantees C = T*k/E * E/k = T — no drops ever
    out, aux = moe_mod.moe(p, x, cfg, capacity_factor=E / k)
    want = moe_mod.moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), E=st.sampled_from([4, 8, 16]))
def test_moe_sort_dispatch_equals_cumsum(seed, E):
    """The §Perf sort-based rank-in-expert == the baseline cumsum ranks."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, E, size=200, dtype=np.int32))
    onehot = jax.nn.one_hot(a, E, dtype=jnp.int32)
    pos_cumsum = jnp.take_along_axis(jnp.cumsum(onehot, 0) - onehot,
                                     a[:, None], axis=1)[:, 0]
    pos_sort = moe_mod._rank_in_expert_sort(a, E)
    np.testing.assert_array_equal(np.asarray(pos_sort),
                                  np.asarray(pos_cumsum))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_sort_dispatch_full_layer(seed):
    cfg = get_config("dbrx-132b", smoke=True)
    key = jax.random.PRNGKey(seed)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          jnp.float32)
    base, _ = moe_mod.moe(p, x, cfg, capacity_factor=2.0)
    fast, _ = moe_mod.moe(p, x, cfg.replace(moe_dispatch="sort"),
                          capacity_factor=2.0)
    np.testing.assert_allclose(np.asarray(base), np.asarray(fast),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_dispatch_parity_under_drops(seed):
    """sort vs one-hot-cumsum dispatch assign identical ranks, so their
    capacity-overflow DROP behavior matches too: at a squeezing capacity
    factor both paths drop the same tokens and emit identical outputs."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)       # E=8, top-2
    key = jax.random.PRNGKey(seed)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    out_sort, aux_s = moe_mod.moe(p, x, cfg.replace(moe_dispatch="sort"),
                                  capacity_factor=0.5)
    out_cum, aux_c = moe_mod.moe(p, x, cfg.replace(moe_dispatch="cumsum"),
                                 capacity_factor=0.5)
    np.testing.assert_array_equal(np.asarray(out_sort), np.asarray(out_cum))
    np.testing.assert_allclose(float(aux_s), float(aux_c), rtol=1e-6)
    # cf=0.5 actually dropped something (else this test is vacuous)
    full, _ = moe_mod.moe(p, x, cfg, capacity_factor=cfg.num_experts
                          / cfg.experts_per_token)
    assert not np.array_equal(np.asarray(out_sort), np.asarray(full))


@pytest.mark.parametrize("dispatch", ["sort", "cumsum"])
def test_moe_equals_reference_no_drop_both_dispatches(dispatch):
    """moe() == dense all-experts moe_reference at no-drop capacity for
    BOTH dispatch implementations."""
    cfg = get_config("dbrx-132b", smoke=True).replace(moe_dispatch=dispatch)
    key = jax.random.PRNGKey(3)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          jnp.float32)
    out, _ = moe_mod.moe(p, x, cfg, capacity_factor=cfg.num_experts
                         / cfg.experts_per_token)
    want = moe_mod.moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_bounded():
    """With cf=1.0, outputs differ from reference only on dropped tokens
    (which fall back to the residual path — zeros here)."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, _ = moe_mod.moe(p, x, cfg, capacity_factor=1.0)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b"])
def test_scan_equals_unrolled_stack(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    a, _, _ = M.forward(params, batch, cfg)
    b, _, _ = M.forward(params, batch, cfg.replace(scan_layers=False))
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-4, atol=2e-4)
