"""Quantization, packing, and digit-decomposition invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import quant as q

BITS = [2, 4, 8]


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(bits, seed):
    rng = np.random.default_rng(seed)
    lo, hi = q.qrange(bits)
    x = rng.integers(lo, hi + 1, size=(5, 16), dtype=np.int8)
    packed = q.pack_bits(jnp.asarray(x), bits)
    assert packed.shape[-1] == 16 // (8 // bits)
    out = q.unpack(packed, bits, x.shape)
    np.testing.assert_array_equal(np.asarray(out), x)


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1),
       signed=st.booleans())
def test_radix4_digits_recompose(bits, seed, signed):
    rng = np.random.default_rng(seed)
    lo, hi = q.qrange(bits) if signed else (0, (1 << bits) - 1)
    x = rng.integers(lo, hi + 1, size=(64,), dtype=np.int32)
    d = q.to_radix4_digits(jnp.asarray(x), bits, signed=signed)
    assert d.shape[0] == q.num_digits(bits)
    np.testing.assert_array_equal(np.asarray(q.from_radix4_digits(d)), x)
    dn = np.asarray(d)
    assert dn[:-1].min() >= 0 and dn[:-1].max() <= 3 if d.shape[0] > 1 else True
    if signed:
        assert dn[-1].min() >= -2 and dn[-1].max() <= 1


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1),
       signed=st.booleans())
def test_bit_planes_recompose(bits, seed, signed):
    rng = np.random.default_rng(seed)
    lo, hi = q.qrange(bits) if signed else (0, (1 << bits) - 1)
    x = rng.integers(lo, hi + 1, size=(32,), dtype=np.int32)
    planes = np.asarray(q.to_bits(jnp.asarray(x), bits, signed=signed))
    recon = sum((1 << i) * planes[i].astype(np.int64) for i in range(bits))
    np.testing.assert_array_equal(recon, x)


@pytest.mark.parametrize("bits", BITS)
def test_quantize_dequantize_error_bound(bits):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    qt = q.quantize(jnp.asarray(x), bits, axis=-1)
    err = np.abs(np.asarray(qt.dequantize()) - x)
    # max error <= scale/2 per channel
    bound = np.asarray(qt.scale) / 2 + 1e-6
    assert (err <= bound).all()


@pytest.mark.parametrize("bits", BITS)
def test_quantize_packed_matches_unpacked(bits):
    rng = np.random.default_rng(1)
    x = rng.normal(size=(4, 32)).astype(np.float32)
    a = q.quantize(jnp.asarray(x), bits, pack=False)
    b = q.quantize(jnp.asarray(x), bits, pack=True)
    np.testing.assert_array_equal(np.asarray(a.values),
                                  np.asarray(b.unpacked_values()))


@pytest.mark.parametrize("bits", [2, 4])
def test_requantize_matches_quantize_of_dequantized(bits):
    """requantize(qt, b) == quantize(qt.dequantize(), b): narrowing an
    8-bit tensor to the draft width is exactly a fresh quantization of
    its dequantized values, and exactly-representable values ({0, 1}
    weights at scale 1) survive the round trip bit-for-bit."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 16)).astype(np.float32)
    qt8 = q.quantize(jnp.asarray(x), 8, axis=-1)
    narrow = q.requantize(qt8, bits, axis=-1)
    ref = q.quantize(qt8.dequantize(), bits, axis=-1)
    assert narrow.bits == bits
    np.testing.assert_array_equal(np.asarray(narrow.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(narrow.scale),
                                  np.asarray(ref.scale))
    # {0, 1} values are exact at any width: lo(2) = -2, hi(2) = 1
    ones = jnp.asarray(rng.integers(0, 2, size=(4, 8)).astype(np.float32))
    exact = q.requantize(q.quantize(ones, 8, axis=-1), bits, axis=-1)
    np.testing.assert_array_equal(np.asarray(exact.dequantize()),
                                  np.asarray(ones))
    # packed output unpacks to the unpacked values
    packed = q.requantize(qt8, bits, axis=-1, pack=True, pack_axis=-2)
    np.testing.assert_array_equal(np.asarray(packed.unpacked_values()),
                                  np.asarray(narrow.values))
