"""Distributed-execution tests on 8 virtual CPU devices (the `multidevice`
marker: standalone runs spawn one subprocess per test so the XLA
device-count flag never leaks; ci.sh batches them in one pass)."""
import pytest
from conftest import run_multidevice as run_sub

pytestmark = pytest.mark.multidevice


def test_sharded_train_step_matches_single_device():
    """pjit'd (2 data × 4 model) train step == unsharded step numerically."""
    out = run_sub("""
from repro.configs import get_config
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.optim import adamw

cfg = get_config("granite-8b", smoke=True).replace(num_layers=2)
params = M.init_params(cfg, jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                      cfg.vocab_size)}

def loss(p, b):
    return M.loss_fn(p, b, cfg)[0]

ref_loss = loss(params, batch)
ref_grad = jax.grad(lambda p: loss(p, batch))(params)

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = shd.activate(mesh)
p_sh = shd.param_shardings(params, ctx)
params_s = jax.device_put(params, p_sh)
b_sh = jax.tree_util.tree_map(
    lambda a: NamedSharding(mesh, P("data", *([None]*(a.ndim-1)))), batch)
batch_s = jax.device_put(batch, b_sh)
got_loss, got_grad = jax.jit(jax.value_and_grad(loss),
                             in_shardings=(p_sh, b_sh))(params_s, batch_s)
np.testing.assert_allclose(float(got_loss), float(ref_loss), rtol=2e-5)
err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                - b.astype(jnp.float32))))
          for a, b in zip(jax.tree_util.tree_leaves(got_grad),
                          jax.tree_util.tree_leaves(ref_grad)))
print("MAXERR", err)
assert err < 5e-4, err
print("SHARDED_OK")
""")
    assert "SHARDED_OK" in out


def test_dp_trainer_compression_and_convergence():
    """shard_map DP trainer: int8+error-feedback grads still converge, and
    one-step compressed grads are close to exact mean grads."""
    out = run_sub("""
from repro.runtime import dp_trainer as dp

mesh = jax.make_mesh((8,), ("data",))
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 4))}
target = jax.random.normal(jax.random.PRNGKey(1), (16, 4))

def loss_fn(p, batch):
    pred = batch @ p["w"]
    want = batch @ target
    return jnp.mean((pred - want) ** 2)

step = dp.make_dp_train_step(loss_fn, mesh, compress=True)
step_exact = dp.make_dp_train_step(loss_fn, mesh, compress=False)
err = dp.init_error_feedback(params, mesh)
batch = jax.random.normal(jax.random.PRNGKey(2), (64, 16))

g1, err1, l1 = step(params, err, batch)
g0, _, _ = step_exact(params, err, batch)
rel = float(jnp.linalg.norm(g1["w"] - g0["w"]) / jnp.linalg.norm(g0["w"]))
print("REL", rel)
assert rel < 0.05, rel

# convergence with compressed grads matches exact-gradient convergence
import copy
finals = []
for st in (step, step_exact):
    p = copy.deepcopy(params)
    e = dp.init_error_feedback(params, mesh)
    for i in range(200):
        g, e, l = st(p, e, batch)
        p = jax.tree_util.tree_map(lambda a, gg: a - 0.05 * gg, p, g)
    finals.append(float(l))
print("FINAL_LOSSES", finals)
assert finals[0] < 0.01 * 37.6           # descended >100x
assert abs(finals[0] - finals[1]) < 0.05 * finals[1] + 1e-6
print("DP_OK")
""")
    assert "DP_OK" in out


def test_multihost_batch_sharding_and_elastic_mesh():
    """Same checkpoint usable across 8-device and 2-device meshes
    (elastic scale-down) with identical loss."""
    out = run_sub("""
from repro.configs import get_config
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.checkpoint import ckpt
import tempfile, os

cfg = get_config("musicgen-large", smoke=True).replace(num_layers=2)
params = M.init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
ckpt.save(d, 0, params)

losses = []
for shape, axes in (((8, 1), ("data", "model")), ((2, 1), ("data", "model"))):
    devs = np.array(jax.devices()[: shape[0] * shape[1]]).reshape(shape)
    mesh = Mesh(devs, axes)
    ctx = shd.activate(mesh)
    p_sh = shd.param_shardings(params, ctx)
    restored = ckpt.restore(d, 0, params, shardings=p_sh)
    B = 8
    batch = {"frame_embeds": jnp.ones((B, 8, cfg.d_model), jnp.float32),
             "labels": jnp.zeros((B, 8), jnp.int32)}
    loss, _ = jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(restored, batch)
    losses.append(float(loss))
    shd.deactivate()
print("LOSSES", losses)
assert abs(losses[0] - losses[1]) < 1e-5
print("ELASTIC_MESH_OK")
""")
    assert "ELASTIC_MESH_OK" in out
