"""EngineOptions / RequestResult surface (runtime/options.py) and the
engine behavior it controls: sectioned-options vs legacy flat-kwarg
construction equivalence, validation error parity with the historic loose
kwargs, the submit-time max_seq budget clamp, per-request stop sets,
abort, and the structured completion record (finish reasons + serving
counters)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.runtime.options import (DebugOptions, EngineOptions,
                                   PagingOptions, RequestResult,
                                   ScheduleOptions, SpeculationOptions)
from repro.runtime.sampling import SamplingConfig
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b", smoke=True)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


# --- construction and validation --------------------------------------------

def test_options_sections_validate_at_construction():
    with pytest.raises(ValueError, match="decode_steps must be >= 1"):
        ScheduleOptions(decode_steps=0)
    with pytest.raises(ValueError, match="kv_layout must be 'paged'"):
        PagingOptions(kv_layout="ragged")
    with pytest.raises(ValueError, match="num_pages must be >= 1"):
        PagingOptions(num_pages=0)
    with pytest.raises(ValueError, match="draft_len must be >= 0"):
        SpeculationOptions(draft_len=-1)
    with pytest.raises(ValueError, match="ngram must be >= 2"):
        SpeculationOptions(ngram=1)
    with pytest.raises(ValueError, match="drafter must be 'ngram' or"):
        SpeculationOptions(drafter="oracle")
    with pytest.raises(ValueError, match="draft_bits"):
        SpeculationOptions(draft_bits=3)
    with pytest.raises(ValueError, match="draft_layers"):
        SpeculationOptions(draft_layers=0)
    with pytest.raises(ValueError, match="sampling method"):
        EngineOptions(sampling="argmax")
    with pytest.raises(TypeError, match="EngineOptions.schedule"):
        EngineOptions(schedule={"num_slots": 2})


def test_build_merges_legacy_kwargs_over_base():
    """EngineOptions.build reproduces the loose-kwarg semantics: sampling
    method + knobs assemble in one shot, eos_id becomes a one-token stop
    set (explicit stop_tokens wins), None means not-given, and unknown
    names raise like bad keywords."""
    o = EngineOptions.build(sampling="top_k", top_k=5, num_slots=3,
                            eos_id=7, draft_len=2)
    assert o.sampling == SamplingConfig(method="top_k", top_k=5)
    assert o.schedule.num_slots == 3
    assert o.schedule.stop_tokens == (7,)
    assert o.speculation.draft_len == 2
    # explicit stop_tokens beats eos_id; base fields survive the merge
    base = EngineOptions(schedule=ScheduleOptions(max_seq=48, seed=9))
    o = EngineOptions.build(base=base, eos_id=7, stop_tokens=(1, 2),
                            num_slots=2)
    assert o.schedule.stop_tokens == (1, 2)
    assert o.schedule.max_seq == 48 and o.schedule.seed == 9
    assert o.schedule.num_slots == 2
    # None = not given (the launcher passes `x or None` everywhere)
    o = EngineOptions.build(num_pages=None, prefix_chunk=None)
    assert o.paging.num_pages is None
    with pytest.raises(ValueError, match="top_k sampling needs top_k"):
        EngineOptions.build(sampling="top_k")
    with pytest.raises(TypeError, match="unknown Engine option 'pages'"):
        EngineOptions.build(pages=4)


def test_engine_validation_errors_survive_the_redesign(granite):
    """The exact error messages older callers match on still raise from
    Engine(...) whichever construction path is used."""
    cfg, params = granite
    with pytest.raises(ValueError, match="decode_steps must be >= 1"):
        Engine(cfg, params, num_slots=1, max_seq=8, decode_steps=0)
    with pytest.raises(ValueError, match="kv_layout must be 'paged'"):
        Engine(cfg, params, num_slots=1, max_seq=8, kv_layout="x")
    with pytest.raises(ValueError, match="dispatch must be 'global'"):
        Engine(cfg, params, num_slots=1, max_seq=8, dispatch="round_robin")


def test_legacy_kwargs_equal_options_construction(granite):
    """Same engine both ways: identical streams, identical baked knobs."""
    cfg, params = granite
    prompt = [2, 4, 6, 8, 2, 4]
    legacy = Engine(cfg, params, num_slots=2, max_seq=32, decode_steps=2,
                    sampling="temperature", temperature=0.7, seed=5,
                    draft_len=3, eos_id=None, check_invariants=True)
    opts = EngineOptions(
        sampling=SamplingConfig(method="temperature", temperature=0.7),
        schedule=ScheduleOptions(num_slots=2, max_seq=32, decode_steps=2,
                                 seed=5),
        speculation=SpeculationOptions(draft_len=3),
        debug=DebugOptions(check_invariants=True))
    modern = Engine(cfg, params, options=opts)
    assert modern.options == legacy.options
    ra = legacy.submit(prompt, 10, seed=1)
    rb = modern.submit(prompt, 10, seed=1)
    legacy.run(), modern.run()
    assert ra.out_tokens == rb.out_tokens
    # per-call legacy kwargs override a base options bundle
    over = Engine(cfg, params, options=opts, decode_steps=1)
    assert over.decode_steps == 1
    assert over.options.schedule.max_seq == 32


# --- submit clamp, stop sets, finish reasons --------------------------------

def test_submit_clamps_budget_to_max_seq_deterministically(granite):
    """Bugfix: len(prompt) + max_new_tokens > max_seq used to run the
    request into the ceiling silently.  Now the budget clamps at submit
    (visible on the Request) and the result says finish_reason='max_seq';
    the emitted stream is unchanged by the clamp."""
    cfg, params = granite
    prompt = np.arange(1, 29, dtype=np.int32)            # plen 28
    eng = Engine(cfg, params, num_slots=1, max_seq=32)
    r = eng.submit(prompt, 16)
    assert r.clamped and r.requested == 16 and r.max_new_tokens == 4
    (res,) = eng.run()
    assert res.finish_reason == "max_seq"
    assert len(res.tokens) == 4
    # an exact fit is not a clamp: the budget is the binding constraint
    eng = Engine(cfg, params, num_slots=1, max_seq=32)
    r = eng.submit(prompt, 4)
    assert not r.clamped
    (res,) = eng.run()
    assert res.finish_reason == "budget" and res.tokens == tuple(r.out_tokens)


def test_per_request_stop_tokens_and_eos_reason(granite):
    cfg, params = granite
    prompt = [5, 9, 5, 9, 5, 9]
    eng = Engine(cfg, params, num_slots=1, max_seq=64)
    ref = eng.submit(prompt, 16)
    eng.run()
    stream = ref.out_tokens
    # multi-token stop set: first member reached wins
    stops = (stream[5], stream[2])
    cut = min(stream.index(s) for s in stops)
    eng = Engine(cfg, params, num_slots=1, max_seq=64)
    r = eng.submit(prompt, 16, stop_tokens=stops)
    (res,) = eng.run()
    assert res.finish_reason == "eos"
    assert list(res.tokens) == stream[:cut + 1]
    # engine-level default stop set applies when submit passes none
    eng = Engine(cfg, params, num_slots=1, max_seq=64,
                 stop_tokens=(stream[2],))
    r = eng.submit(prompt, 16)
    eng.run()
    assert r.result.finish_reason == "eos"
    assert list(r.result.tokens) == stream[:stream.index(stream[2]) + 1]
    # a stop set past the baked capacity is rejected eagerly
    with pytest.raises(ValueError, match="stop_tokens"):
        eng.submit(prompt, 4, stop_tokens=(1, 2, 3, 4, 5))


def test_abort_queued_and_running(granite):
    cfg, params = granite
    prompt = [3, 1, 4, 1, 5, 9]
    eng = Engine(cfg, params, num_slots=1, max_seq=64,
                 check_invariants=True)
    run_req = eng.submit(prompt, 30)
    queued = eng.submit(prompt, 30)
    # queued: removed before it ever runs, zero tokens
    assert eng.abort(queued)
    assert queued.done and queued.result.finish_reason == "aborted"
    assert queued.result.tokens == ()
    eng.step()
    # running: slot freed immediately, emitted tokens kept
    held = eng.pages_in_use
    assert eng.abort(run_req)
    assert run_req.result.finish_reason == "aborted"
    assert len(run_req.result.tokens) >= 1
    assert eng.pages_in_use < held
    assert not eng.abort(run_req)          # already finished
    # the engine keeps serving after aborts; run() drains everything
    # completed since the last drain, the aborts included
    nxt = eng.submit(prompt, 4)
    results = eng.run()
    assert [r.uid for r in results] == [queued.uid, run_req.uid, nxt.uid]
    assert nxt.result.finish_reason == "budget"


def test_run_returns_results_in_completion_order(granite):
    cfg, params = granite
    eng = Engine(cfg, params, num_slots=2, max_seq=48)
    short = eng.submit([1, 2, 3], 3)
    long = eng.submit([4, 5, 6], 12)
    results = eng.run()
    assert [r.uid for r in results] == [short.uid, long.uid]
    assert all(isinstance(r, RequestResult) for r in results)
    assert eng.run() == []                 # drained
    assert short.result is results[0]


def test_result_counters_prefill_and_pages_shared(granite):
    """prefill_tokens counts the prompt rows whose compute actually ran
    (warm prefix admissions skip the shared pages), and pages_shared
    counts the read-only page mappings."""
    cfg, params = granite
    rng = np.random.default_rng(0)
    sys_p = list(rng.integers(1, cfg.vocab_size, 2 * cfg.page_size))
    eng = Engine(cfg, params, num_slots=2, max_seq=96, prefix_cache=True)
    cold = eng.submit(sys_p + [1, 2, 3], 4)
    eng.run()
    warm = eng.submit(sys_p + [7, 8, 9], 4)
    eng.run()
    assert cold.result.prefill_tokens == len(sys_p) + 3
    assert cold.result.pages_shared == 0
    assert warm.result.pages_shared == 2
    assert warm.result.prefill_tokens == 3
    assert warm.result.finish_reason == "budget"


def test_request_result_is_validated_and_frozen():
    r = RequestResult(uid=0, tokens=[np.int32(3), 4], finish_reason="eos")
    assert r.tokens == (3, 4) and all(isinstance(t, int) for t in r.tokens)
    with pytest.raises(ValueError, match="finish_reason"):
        RequestResult(uid=0, tokens=(), finish_reason="done")
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.tokens = ()
