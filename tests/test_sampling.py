"""On-device sampling (runtime/sampling.py) unit tests.

The serving parity suite exercises sampling through the engine; these
tests pin the per-method masking semantics directly — most importantly
the top-k regression: a value-threshold mask (`l >= kth`) kept every
logit tied with the k-th largest, so tie-heavy distributions sampled from
a nucleus larger than k.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sampling import SamplingConfig, request_keys, sample

jax.config.update("jax_platform_name", "cpu")


def _draws(logits, sc, n=64, seed=0):
    """Sampled token set over n independent keys for one logits row."""
    keys = request_keys(jax.random.PRNGKey(seed),
                        jnp.arange(n, dtype=jnp.int32))
    toks, new_keys = sample(jnp.broadcast_to(logits, (n, logits.shape[-1])),
                            keys, sc)
    assert new_keys.shape == keys.shape
    return set(np.asarray(toks).tolist())


def test_top_k_ties_keep_exactly_k():
    """Regression: with every logit tied, `l >= kth` kept the WHOLE vocab.
    The rank-based mask keeps exactly k tokens (lowest indices win ties,
    matching lax.top_k's deterministic tie-break)."""
    flat = jnp.zeros((16,), jnp.float32)
    got = _draws(flat, SamplingConfig(method="top_k", top_k=3), n=256)
    assert got == {0, 1, 2}


def test_top_k_ties_straddling_the_cutoff():
    """Ties straddling the k-th rank: logits [9, 7, 7, 7, 1, ...] with k=2
    must keep token 0 and exactly ONE of the tied 7s (index 1), never all
    three."""
    l = jnp.asarray([9.0, 7.0, 7.0, 7.0, 1.0, 0.0, 0.0, 0.0])
    got = _draws(l, SamplingConfig(method="top_k", top_k=2), n=256)
    assert got == {0, 1}


def test_top_k_distinct_logits_unchanged():
    """No ties: the rank mask and the old value threshold agree — the k
    largest logits stay, everything else is excluded."""
    l = jnp.asarray([5.0, 3.0, 4.0, 1.0, 2.0, 0.0])
    got = _draws(l, SamplingConfig(method="top_k", top_k=3), n=256)
    assert got == {0, 1, 2} or got <= {0, 1, 2}   # k=3 keeps logits 5,4,3


def test_top_k_covers_whole_vocab_when_k_exceeds_it():
    l = jnp.asarray([0.0, 0.0, 0.0, 0.0])
    got = _draws(l, SamplingConfig(method="top_k", top_k=9), n=512)
    assert got == {0, 1, 2, 3}


def test_top_p_keeps_best_token_and_truncates_tail():
    """A near-deterministic distribution at top_p=0.5 collapses to the
    argmax; a flat one keeps more than a single token."""
    sharp = jnp.asarray([10.0, 0.0, 0.0, 0.0])
    assert _draws(sharp, SamplingConfig(method="top_p", top_p=0.5)) == {0}
    flat = jnp.zeros((4,), jnp.float32)
    got = _draws(flat, SamplingConfig(method="top_p", top_p=0.9), n=256)
    assert len(got) > 1


def test_greedy_consumes_no_randomness():
    logits = jnp.asarray([[1.0, 5.0, 2.0], [3.0, 0.0, 1.0]])
    keys = request_keys(jax.random.PRNGKey(1), jnp.asarray([4, 9]))
    toks, new_keys = sample(logits, keys, SamplingConfig(method="greedy"))
    assert np.asarray(toks).tolist() == [1, 0]
    assert (np.asarray(new_keys) == np.asarray(keys)).all()


def test_stochastic_methods_advance_keys_deterministically():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]])
    keys = request_keys(jax.random.PRNGKey(2), jnp.asarray([7]))
    sc = SamplingConfig(method="temperature", temperature=0.7)
    t1, k1 = sample(logits, keys, sc)
    t2, k2 = sample(logits, keys, sc)
    assert np.asarray(t1).tolist() == np.asarray(t2).tolist()
    assert (np.asarray(k1) == np.asarray(k2)).all()
    assert not (np.asarray(k1) == np.asarray(keys)).all()
