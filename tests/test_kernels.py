"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quant import qrange
from repro.kernels import ops, ref
from repro.kernels.bramac_matmul import bramac_matmul
from repro.kernels.mac2_kernel import mac2_mvm_kernel

jax.config.update("jax_platform_name", "cpu")

BITS = [2, 4, 8]


def rand_q(rng, bits, shape, signed=True):
    lo, hi = qrange(bits) if signed else (0, (1 << bits) - 1)
    return rng.integers(lo, hi + 1, size=shape, dtype=np.int8)


# ---------------------------------------------------------------------------
# Production radix-4 kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits_a", BITS)
@pytest.mark.parametrize("bits_w", BITS)
@pytest.mark.parametrize("shape", [(8, 16, 8), (16, 32, 24), (128, 128, 128)])
def test_bramac_matmul_shapes(bits_a, bits_w, shape):
    if shape == (128, 128, 128) and (bits_a, bits_w) != (4, 4):
        pytest.skip("full-block case covered once (interpret mode is slow)")
    M, K, N = shape
    rng = np.random.default_rng(hash((bits_a, bits_w, shape)) % 2**31)
    xq = jnp.asarray(rand_q(rng, bits_a, (M, K)))
    wq = jnp.asarray(rand_q(rng, bits_w, (K, N)))
    xs = jnp.asarray(rng.uniform(0.5, 2.0, (M, 1)).astype(np.float32))
    ws = jnp.asarray(rng.uniform(0.5, 2.0, (1, N)).astype(np.float32))
    got = ops.quant_matmul(xq, wq, xs, ws, bits_a=bits_a, bits_w=bits_w)
    want = ref.quant_matmul_exact(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_bramac_matmul_dtypes(out_dtype):
    rng = np.random.default_rng(0)
    xq = jnp.asarray(rand_q(rng, 4, (16, 32)))
    wq = jnp.asarray(rand_q(rng, 4, (32, 16)))
    xs = jnp.ones((16, 1), jnp.float32)
    ws = jnp.ones((1, 16), jnp.float32)
    got = ops.quant_matmul(xq, wq, xs, ws, bits_a=4, bits_w=4,
                           out_dtype=out_dtype)
    assert got.dtype == out_dtype
    want = ref.quant_matmul_exact(xq, wq, xs, ws, out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2)


def test_bramac_matmul_unsigned_inputs():
    rng = np.random.default_rng(3)
    xq = jnp.asarray(rand_q(rng, 4, (8, 16), signed=False))
    wq = jnp.asarray(rand_q(rng, 4, (16, 8)))
    one = jnp.ones((1, 1), jnp.float32)
    got = ops.quant_matmul(xq, wq, one, one, bits_a=4, bits_w=4, signed=False)
    want = ref.quant_matmul_exact(xq, wq, one, one)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_bramac_matmul_packed_weights():
    rng = np.random.default_rng(4)
    xq = jnp.asarray(rand_q(rng, 4, (16, 64)))
    wq = jnp.asarray(rand_q(rng, 4, (64, 32)))
    one = jnp.ones((1, 1), jnp.float32)
    got = ops.quant_matmul(xq, wq, one, one, bits_a=4, bits_w=4,
                           w_packed=True)
    want = ref.quant_matmul_exact(xq, wq, one, one)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1))
def test_digit_ref_matches_exact(bits, seed):
    """The digit-dataflow reference is exact for any quantized input."""
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rand_q(rng, bits, (8, 24)))
    wq = jnp.asarray(rand_q(rng, bits, (24, 8)))
    xs = jnp.asarray(rng.uniform(0.1, 2, (8, 1)).astype(np.float32))
    ws = jnp.asarray(rng.uniform(0.1, 2, (1, 8)).astype(np.float32))
    a = ref.quant_matmul_digit_ref(xq, wq, xs, ws, bits_a=bits)
    b = ref.quant_matmul_exact(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# Faithful dummy-array kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shape", [(8, 6), (16, 10), (40, 8)])
def test_mac2_mvm_kernel(bits, shape):
    R, C = shape
    rng = np.random.default_rng(hash((bits, shape)) % 2**31)
    w = jnp.asarray(rand_q(rng, bits, (R, C)))
    x = jnp.asarray(rand_q(rng, bits, (C,)))
    got = mac2_mvm_kernel(w, x, bits=bits, block=8, interpret=True)
    want = ref.mac2_mvm_ref(w, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mac2_mvm_kernel_unsigned():
    rng = np.random.default_rng(9)
    w = jnp.asarray(rand_q(rng, 4, (8, 6)))
    x = jnp.asarray(rand_q(rng, 4, (6,), signed=False))
    got = mac2_mvm_kernel(w, x, bits=4, signed=False, block=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.mac2_mvm_ref(w, x)))


def test_kernel_blocks_fit_vmem():
    """Default and scaled-up block shapes stay inside the v5e VMEM budget
    (with double-buffering headroom), and MXU dims stay 128-aligned."""
    for block in [(128, 128, 128), (256, 128, 512), (512, 512, 512)]:
        assert ops.kernel_vmem_bytes(block) < ops.VMEM_BUDGET, block
        assert all(b % 128 == 0 for b in block)
    # packed int4 weights halve the resident tile
    assert ops.kernel_vmem_bytes((128, 512, 512), w_packed=True) < \
        ops.kernel_vmem_bytes((128, 512, 512), w_packed=False)
    # something must NOT fit, or the budget check is vacuous
    assert ops.kernel_vmem_bytes((1024, 1024, 2048)) > ops.VMEM_BUDGET


@pytest.mark.parametrize("block", [(16, 16, 16), (8, 32, 16)])
def test_bramac_matmul_block_sweep(block):
    """Kernel correctness is block-shape independent."""
    rng = np.random.default_rng(7)
    M, K, N = 32, 64, 32
    xq = jnp.asarray(rand_q(rng, 4, (M, K)))
    wq = jnp.asarray(rand_q(rng, 4, (K, N)))
    xs = jnp.ones((M, 1), jnp.float32)
    ws = jnp.ones((1, N), jnp.float32)
    got = bramac_matmul(xq, wq, xs, ws, bits_a=4, bits_w=4, block=block,
                        interpret=True)
    want = ref.quant_matmul_exact(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# STE dense
# ---------------------------------------------------------------------------

def test_bramac_dense_forward_and_grad():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))

    y = ops.bramac_dense(x, w, 8, 8)
    # 8-bit fake-quant ≈ float matmul within a few percent
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=0.15, atol=0.1)

    def loss(x, w):
        return jnp.sum(ops.bramac_dense(x, w, 8, 8) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert np.isfinite(np.asarray(gx)).all()
    assert np.isfinite(np.asarray(gw)).all()
