"""Allocator-invariant property tests for the refcounted KV page pool
(runtime/pages.py).

Random admission / share / release / eviction schedules are replayed
against BOTH the device allocator (`admit_update` / `release` — jit'd,
exactly as the engine calls them) and the `HostPool` mirror; after every
step the two must agree bit for bit, and the module's documented
invariants must hold:

  I1  refcounts never negative;
  I2  a page is free iff refcount 0 (grants draw only from refcount-0
      pages; release-to-zero returns a page to the free set);
  I3  sum of per-slot page counts == total live refs minus cache-held
      references;
  I4  grant order deterministic — lowest free page id first, admitting
      slots in ascending slot order (re-running a schedule reproduces
      the same tables exactly).

hypothesis-optional per ROADMAP policy: `_hypothesis_compat` replays a
deterministic example grid when the real library is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.runtime import pages as pg

jax.config.update("jax_platform_name", "cpu")


def _run_schedule(seed: int, S: int, P: int, mp: int, steps: int):
    """Random interleaving of admissions (with shares drawn from live
    cached pages), releases, and cache-ref registrations/evictions,
    applied to device + mirror in lockstep.  Returns the final pair."""
    rng = np.random.default_rng(seed)
    pool = pg.init_pool(S, mp, P)
    host = pg.HostPool(P, S)
    occupied = [False] * S
    cached: set[int] = set()            # pages holding a +1 cache ref

    for _ in range(steps):
        free_ids = np.flatnonzero(host.refs == 0)
        op = rng.integers(3)
        if op == 0:
            # admit 1..S free slots with shares from cached pages
            slots = [s for s in range(S) if not occupied[s]]
            rng.shuffle(slots)
            slots = sorted(slots[:max(1, len(slots) // 2)])
            admitting = np.zeros(S, bool)
            shared = np.zeros((S, mp), np.int32)
            n_shared = np.zeros(S, np.int32)
            new_pages = np.zeros(S, np.int32)
            grants = []
            free_cnt = free_ids.size
            for s in slots:
                sh = list(rng.permutation(sorted(cached)))[
                    :int(rng.integers(0, min(len(cached), mp - 1) + 1))]
                fresh = int(rng.integers(1, mp - len(sh) + 1))
                if fresh > free_cnt:
                    break               # FIFO stall, like the engine
                free_cnt -= fresh
                admitting[s] = True
                shared[s, :len(sh)] = sh
                n_shared[s] = len(sh)
                new_pages[s] = fresh
                occupied[s] = True
                grants.append((s, sh, fresh))
            host.admit_round(grants, {})
            pool = jax.jit(pg.admit_update)(
                pool, jnp.asarray(admitting), jnp.asarray(shared),
                jnp.asarray(n_shared), jnp.asarray(new_pages),
                jnp.zeros(P, jnp.int32), jnp.zeros(P, jnp.int32))
        elif op == 1:
            # release every occupied slot independently with p=1/2
            dead = np.array([occupied[s] and bool(rng.integers(2))
                             for s in range(S)])
            for s in np.flatnonzero(dead):
                host.release_slot(int(s))
                occupied[s] = False
            pool = jax.jit(pg.release)(pool, jnp.asarray(dead))
        else:
            # flip cache refs: register a live uncached page, or drop one
            delta = {}
            live = [p for p in np.flatnonzero(host.refs > 0)
                    if p not in cached]
            if live and rng.integers(2):
                p = int(live[int(rng.integers(len(live)))])
                cached.add(p)
                delta[p] = 1
            elif cached:
                p = int(sorted(cached)[int(rng.integers(len(cached)))])
                cached.discard(p)
                delta[p] = -1
            if delta:
                host.apply_register(delta)
                arr = np.zeros(P, np.int32)
                for p, d in delta.items():
                    arr[p] = d
                pool = pg.PagePool(pool.refs + jnp.asarray(arr),
                                   pool.tables, pool.n_pages, pool.owned)
        _check(pool, host, cached)
    return pool, host


def _check(pool, host, cached):
    refs = np.asarray(pool.refs)
    assert (refs >= 0).all(), refs                                    # I1
    np.testing.assert_array_equal(refs, host.refs)                    # mirror
    assert int((refs == 0).sum()) == host.free_pages                  # I2
    n_pages = np.asarray(pool.n_pages)
    tables = np.asarray(pool.tables)
    owned = np.asarray(pool.owned)
    for s in range(len(host.slot_tables)):
        t = host.slot_tables[s]
        assert int(n_pages[s]) == len(t)
        assert list(tables[s, :len(t)]) == t
        assert list(owned[s, :len(t)]) == host.slot_owned[s]
    assert int(n_pages.sum()) == int(refs.sum()) - len(cached)        # I3
    # at most one owner per page (I5's bookkeeping half)
    owners = [int(tables[s, j]) for s in range(len(host.slot_tables))
              for j in range(int(n_pages[s])) if owned[s, j]]
    assert len(owners) == len(set(owners)), owners


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.sampled_from([1, 2, 4]),
       P=st.sampled_from([4, 8, 16]), steps=st.sampled_from([4, 8, 12]))
def test_allocator_invariants_random_schedules(seed, S, P, steps):
    mp = max(2, P // max(S, 2))
    _run_schedule(seed, S, P, mp, steps)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_grant_order_deterministic(seed):
    """I4: replaying the same schedule yields identical block tables and
    refcounts — grants are a pure function of pool state (lowest free id
    first, slots ascending), with no hidden iteration-order dependence."""
    a_pool, a_host = _run_schedule(seed, 3, 12, 4, 10)
    b_pool, b_host = _run_schedule(seed, 3, 12, 4, 10)
    np.testing.assert_array_equal(np.asarray(a_pool.refs),
                                  np.asarray(b_pool.refs))
    np.testing.assert_array_equal(np.asarray(a_pool.tables),
                                  np.asarray(b_pool.tables))
    assert a_host.slot_tables == b_host.slot_tables


def test_grant_fills_lowest_free_ids_first():
    """I4, pinned concretely: with pages {1, 4} busy, a 3-page grant to
    slots 0 and 2 takes ids (0, 2) and (3,) in slot order."""
    pool = pg.init_pool(3, 2, 6)
    host = pg.HostPool(6, 3)
    # occupy pages 1 and 4 via slot 1
    adm = np.array([False, True, False])
    pre = pg.admit_update(pool, jnp.asarray(adm),
                          jnp.zeros((3, 2), jnp.int32),
                          jnp.zeros(3, jnp.int32),
                          jnp.asarray([0, 2, 0], np.int32),
                          jnp.zeros(6, jnp.int32), jnp.zeros(6, jnp.int32))
    host.admit_round([(1, [], 2)], {})
    assert host.slot_tables[1] == [0, 1]
    # release slot 1, then hand pages 0 and 1 a fake cache ref via
    # registration so the NEXT grant must skip busy ids... keep page 1
    # and 4: simpler — re-admit slot 1 with 2 pages after seeding refs
    pre = pg.release(pre, jnp.asarray([False, True, False]))
    host.release_slot(1)
    seed_delta = {1: 1, 4: 1}
    arr = np.zeros(6, np.int32)
    for p, d in seed_delta.items():
        arr[p] = d
    host.apply_register(seed_delta)
    pre = pg.PagePool(pre.refs + jnp.asarray(arr), pre.tables,
                      pre.n_pages, pre.owned)
    adm = np.array([True, False, True])
    got = pg.admit_update(pre, jnp.asarray(adm),
                          jnp.zeros((3, 2), jnp.int32),
                          jnp.zeros(3, jnp.int32),
                          jnp.asarray([2, 0, 1], np.int32),
                          jnp.zeros(6, jnp.int32), jnp.zeros(6, jnp.int32))
    host.admit_round([(0, [], 2), (2, [], 1)], {})
    assert host.slot_tables[0] == [0, 2] and host.slot_tables[2] == [3]
    np.testing.assert_array_equal(np.asarray(got.tables[0]), [0, 2])
    assert int(got.tables[2, 0]) == 3
    np.testing.assert_array_equal(np.asarray(got.refs),
                                  host.refs)
