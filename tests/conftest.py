"""Shared pytest config: the `multidevice` marker + its runner.

Distributed tests need 8 virtual host devices, which XLA only grants via
`XLA_FLAGS=--xla_force_host_platform_device_count=8` *before* jax import —
a process-global flag that must not leak into the rest of the suite.  The
convention (ROADMAP.md §CI):

  * mark the test `@pytest.mark.multidevice` and run its body through
    `run_multidevice(code)` below;
  * under plain `pytest` each test spawns one subprocess with the flag set
    (isolated, but ~2s interpreter+jax startup per test);
  * `scripts/ci.sh` runs the marked subset in ONE 8-virtual-device pass —
    it sets XLA_FLAGS for `pytest -m multidevice`, and `run_multidevice`
    detects the already-virtualized process and executes in-process.
"""
from __future__ import annotations

import contextlib
import io
import os
import signal
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEVICE_FLAG = "--xla_force_host_platform_device_count=8"

# Names every multidevice snippet can assume are bound — exec'd by BOTH
# modes (the subprocess prepends _ENV_PRELUDE; in-process the env/path are
# already right), so the two can't drift.
COMMON_IMPORTS = (
    'import os, sys\n'
    'import jax, numpy as np\n'
    'import jax.numpy as jnp\n'
    'from jax.sharding import Mesh, NamedSharding, PartitionSpec as P\n'
)
_ENV_PRELUDE = (
    'import os\n'
    f'os.environ["XLA_FLAGS"] = "{DEVICE_FLAG}"\n'
    'import sys\n'
    'sys.path.insert(0, "src")\n'
)


# (the `multidevice` marker itself is registered once, in pyproject.toml's
# [tool.pytest.ini_options] markers list)


def _in_process_capable() -> bool:
    if DEVICE_FLAG not in os.environ.get("XLA_FLAGS", ""):
        return False
    import jax
    return jax.device_count() >= 8


def run_multidevice(code: str, timeout: int = 600) -> str:
    """Run a multidevice test snippet; returns its stdout.

    Subprocess with the XLA flag by default; in-process when this process
    already has the 8 virtual devices (ci.sh's `-m multidevice` pass)."""
    if not _in_process_capable():
        out = subprocess.run(
            [sys.executable, "-c", _ENV_PRELUDE + COMMON_IMPORTS + code],
            cwd=REPO_ROOT, capture_output=True, text=True, timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    src = os.path.join(REPO_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    g: dict = {}
    buf = io.StringIO()
    # exec has no subprocess timeout — use SIGALRM so a hung collective
    # fails THIS test instead of stalling the whole ci.sh pass
    def _alarm(signum, frame):
        raise TimeoutError(f"multidevice snippet exceeded {timeout}s "
                           f"in-process")
    old_handler = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(timeout)
    try:
        with contextlib.redirect_stdout(buf):
            exec(compile(COMMON_IMPORTS + code, "<multidevice>", "exec"), g)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)
        # snippets may activate() a process-global sharding ctx; never let
        # it leak into the next in-process test
        from repro.parallel import sharding
        sharding.deactivate()
    return buf.getvalue()
