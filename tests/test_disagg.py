"""Disaggregated prefill/decode serving (runtime/scheduler.py +
runtime/workers.py + runtime/serve.py).

The contract under test: splitting the engine into a prefill worker (its
own slot set and page pool) and a decode worker, with finished prompts'
KV pages handed off at page granularity (pages.export_pages ->
import_pages + adopt), must change NOTHING about the emitted streams.
Greedy streams are bit-identical disagg vs colocated for every
pool-representable cache architecture (gqa, mla, int8-KV), under
staggered admissions.  Every engine here runs with
`check_invariants=True`, so each assertion also re-proves I1-I6 on BOTH
HostPool mirrors after every transfer round plus the I7 content check
(re-exporting the destination pages and comparing them bit-for-bit
against the tiles that were moved).

Also covered: decode-pool pressure during transfer (a dry decode pool
must backpressure the handoff, never leak a refcount), the
configuration validation surface (dense / recurrent / mesh / remote
roles), and abort of a prompt that finished prefill but never
transferred."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")

# every arch whose cache is fully pool-representable (recurrent-hybrid
# state has no page representation — covered by the validation test)
ARCHS = {
    "gqa": ("granite-8b", {}),
    "mla": ("minicpm3-4b", {}),
    "int8kv": ("granite-8b", {"quant_kv": True}),
}


def _setup(name):
    arch, over = ARCHS[name]
    cfg = get_config(arch, smoke=True)
    if over:
        cfg = cfg.replace(**over)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _serve_staggered(cfg, params, prompts, news, **kw):
    """First two requests admitted, a few ticks run, then the rest
    arrive mid-flight — so transfers interleave with live decode and
    later admissions land while earlier requests still hold pages."""
    with Engine(cfg, params, num_slots=2, max_seq=64, kv_layout="paged",
                prefix_cache=False, check_invariants=True, **kw) as eng:
        reqs = [eng.submit(p, n) for p, n in zip(prompts[:2], news[:2])]
        eng.step()
        eng.step()
        reqs += [eng.submit(p, n)
                 for p, n in zip(prompts[2:], news[2:])]
        eng.run()
        assert all(r.done for r in reqs)
        # both pools fully drained: no slot holds a reference and (with
        # the prefix cache off) no page is retained on either side
        assert eng.pages_in_use == 0
        assert eng.sched.pool.pages_in_use == 0
        stats = eng.disagg_stats()
        return [r.out_tokens for r in reqs], stats


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_disagg_greedy_parity_staggered(name):
    """Greedy streams bit-identical disagg vs colocated on every
    pool-representable cache architecture, with requests arriving in
    waves; the handoff actually ran (pages moved through the decode
    pool) and both mirrors passed I1-I7 after every transfer round."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(0)
    lens = (3, 17, 29, 9, 40)
    news = (5, 7, 4, 6, 3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in lens]
    colo, cs = _serve_staggered(cfg, params, prompts, news)
    disagg, ds = _serve_staggered(cfg, params, prompts, news, disagg=True)
    assert colo == disagg
    assert not cs["enabled"] and ds["enabled"]
    assert ds["pages_transferred"] >= len(prompts)   # >= 1 page each
    assert ds["transfer_rounds"] >= 1
    assert 0 < ds["decode_pages_high_water"] <= ds["decode_pages"]
    assert 0 < ds["prefill_pages_high_water"] <= ds["prefill_pages"]


def test_decode_pool_pressure_backpressures_transfer():
    """A decode pool too small for two in-flight requests: the second
    finished prompt must WAIT in the ready queue (transfer
    backpressured, counted), then move once the first request's pages
    free up — everything completes, streams match colocated, and
    neither pool leaks a single refcount."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(1)
    # 40-token prompts + 10 new tokens -> 50 rows -> 4 pages each with
    # the 16-row page; decode pool of 5 fits only one request at a time
    prompts = [rng.integers(0, cfg.vocab_size, size=40) for _ in range(3)]
    news = (10, 10, 10)
    kw = dict(num_slots=2, max_seq=64, kv_layout="paged",
              prefix_cache=False, check_invariants=True)
    with Engine(cfg, params, num_pages=5, disagg=True,
                prefill_pages=8, **kw) as eng:
        reqs = [eng.submit(p, n) for p, n in zip(prompts, news)]
        eng.run()
        assert all(r.done for r in reqs)
        stats = eng.disagg_stats()
        assert stats["transfers_backpressured"] > 0
        assert stats["decode_pages_high_water"] <= 5
        assert eng.pages_in_use == 0                 # decode pool drained
        assert eng.sched.pool.pages_in_use == 0      # prefill pool drained
        assert eng.pool.slot_refs_total == 0
        streams = [r.out_tokens for r in reqs]
    with Engine(cfg, params, **kw) as eng:
        reqs = [eng.submit(p, n) for p, n in zip(prompts, news)]
        eng.run()
        assert streams == [r.out_tokens for r in reqs]


def test_abort_before_transfer_releases_prefill_pages():
    """Aborting a request that finished prefill but has not yet been
    handed to the decode pool must release its prefill pages and report
    finish_reason='aborted' — no transfer, no leak."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=40) for _ in range(2)]
    with Engine(cfg, params, num_slots=1, max_seq=64, kv_layout="paged",
                num_pages=4, prefill_pages=8, prefill_slots=2,
                disagg=True, prefix_cache=False,
                check_invariants=True) as eng:
        r0 = eng.submit(prompts[0], 10)
        r1 = eng.submit(prompts[1], 10)
        # one step: both prompts prefill (2 prefill slots) but only r0
        # fits the single decode slot; r1 sits in the ready queue
        eng.step()
        assert eng.sched.ready and eng.sched.ready[0].uid == r1.uid
        assert eng.abort(r1)
        assert not eng.sched.ready
        eng.run()
        assert r0.done and r1.done
        assert r1.result.finish_reason == "aborted"
        assert eng.pages_in_use == 0
        assert eng.sched.pool.pages_in_use == 0


def test_disagg_validation_surface():
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(cfg, params, num_slots=2, max_seq=64, kv_layout="dense",
               disagg=True)
    with pytest.raises(NotImplementedError, match="multi-process"):
        Engine(cfg, params, num_slots=2, max_seq=64, kv_layout="paged",
               disagg=True, role="prefill")
    with pytest.raises(NotImplementedError, match="multi-process"):
        Engine(cfg, params, num_slots=2, max_seq=64, kv_layout="paged",
               disagg=True, role="decode")
    with pytest.raises(ValueError, match="mesh"):
        Engine(cfg, params, num_slots=2, max_seq=64, kv_layout="paged",
               disagg=True, mesh="model=1")
    rcfg = get_config("jamba-1.5-large-398b", smoke=True)
    rparams = M.init_params(rcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="page representation"):
        Engine(rcfg, rparams, num_slots=2, max_seq=64, kv_layout="paged",
               disagg=True)


def test_disagg_disables_prefix_and_speculation():
    """Prefix caching and speculation opt out silently under disagg (no
    page representation for drafter state; cached prefixes live in the
    prefill pool which the decode worker cannot see)."""
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with Engine(cfg, params, num_slots=2, max_seq=64, kv_layout="paged",
                disagg=True, draft_len=3, drafter="ngram") as eng:
        assert eng.prefix is None
        assert eng.draft_len == 0
        r = eng.submit(np.arange(1, 9), 5)
        eng.run()
        assert r.done and len(r.out_tokens) == 5


# --- construction failure / close() regression ------------------------------

def test_close_is_idempotent():
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=1, max_seq=32)
    eng.close()
    eng.close()                                      # second close: no-op


def test_failed_construction_releases_sharding_ctx():
    """An Engine whose __init__ raises partway must leave no
    process-global sharding context active — whether the failure lands
    BEFORE the mesh context is entered (invalid mesh spec) or AFTER
    (drafter validation) — and a subsequent Engine must work."""
    from repro.parallel import sharding as shd

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # (a) mesh build fails before activation: 3-way model parallelism
    # cannot be laid out on a single CPU device
    with pytest.raises(ValueError):
        Engine(cfg, params, num_slots=1, max_seq=32, mesh="model=3")
    assert shd.active() is None
    # (b) failure AFTER the sharding ctx is active: the smoke config has
    # 2 layers, so draft_layers=3 fails QuantDrafter validation deep in
    # _build — close() in the except path must release the ctx
    with pytest.raises(ValueError, match="draft_layers"):
        Engine(cfg, params, num_slots=1, max_seq=32, mesh="model=1",
               draft_len=3, drafter="model", draft_layers=3)
    assert shd.active() is None
    # the process is not poisoned: a fresh engine still serves
    with Engine(cfg, params, num_slots=1, max_seq=32) as eng:
        r = eng.submit([1, 2, 3], 4)
        eng.run()
        assert r.done and len(r.out_tokens) == 4
