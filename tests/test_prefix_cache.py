"""Copy-on-write prefix caching across the serving stack (runtime/pages.py
+ runtime/serve.py).

The contract under test: warm-prefix admission maps cached pages into the
admitting slot's block table read-only and skips their prefill compute —
and NOTHING about the emitted streams may change.  Greedy streams must be
bit-identical across {prefix cache on, off} x {paged, dense} for every
cache architecture (gqa, mla, int8-KV, recurrent-hybrid — the last opts
out of sharing but must still stream identically), including prompts that
diverge from a cached prefix mid-page (the copy-on-write split).  All
engines here run with `check_invariants=True`, so every assertion also
re-proves the HostPool mirror == device allocator equality after each
sync."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")

ARCHS = {
    "gqa": ("granite-8b", {}),
    "mla": ("minicpm3-4b", {}),
    "int8kv": ("granite-8b", {"quant_kv": True}),
    "recurrent": ("jamba-1.5-large-398b", {}),
}


def _setup(name):
    arch, over = ARCHS[name]
    cfg = get_config(arch, smoke=True)
    if over:
        cfg = cfg.replace(**over)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _serve_staggered(cfg, params, prompts, news, **kw):
    """Requests submitted in waves (producer finishes before consumers
    arrive — same-round requests never match each other by design), so
    later requests exercise warm admission when the cache is on."""
    eng = Engine(cfg, params, num_slots=2, max_seq=96, **kw)
    outs = []
    for p, n in zip(prompts, news):
        r = eng.submit(p, n)
        eng.run()
        outs.append(r.out_tokens)
        assert r.done
    return outs, eng


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefix_parity_on_off_dense(name):
    """Identical system prompt across staggered requests: bit-identical
    streams with cache on vs off vs the dense oracle, on every cache
    architecture."""
    cfg, params = _setup(name)
    rng = np.random.default_rng(0)
    sysp = rng.integers(0, cfg.vocab_size, size=40)     # shared 40 tokens
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab_size,
                                                  size=k)])
               for k in (3, 7, 5)]
    news = (5, 4, 6)
    warm, eng = _serve_staggered(cfg, params, prompts, news,
                                 check_invariants=True)
    cold, _ = _serve_staggered(cfg, params, prompts, news,
                               prefix_cache=False)
    dense, _ = _serve_staggered(cfg, params, prompts, news,
                                kv_layout="dense")
    assert warm == cold == dense
    st = eng.prefix_stats()
    if name == "recurrent":
        # recurrent state accumulates over every token: sharing is
        # silently disabled, but the streams above already proved parity
        assert not st["enabled"]
    else:
        # requests 2 and 3 hit the registered 40-token prefix: 2 full
        # pages each mapped read-only, 32 tokens of prefill skipped
        assert st["hits"] == 2 and st["tokens_skipped"] == 64
        assert eng.pages_shared_high_water >= 2


def test_cow_divergence_mid_page():
    """Two requests sharing 24 tokens with prefix_chunk=8 < page_size=16:
    the second's match ends mid-page, so its partial page arrives as a
    private copy (copy-on-write) while the cached page is never written —
    streams must still bit-match the cold path, and a THIRD request
    re-matching the full first prompt proves the cached page survived the
    second request's divergent writes."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(1)
    stem = rng.integers(0, cfg.vocab_size, size=24)
    prompts = [np.concatenate([stem, rng.integers(0, cfg.vocab_size,
                                                  size=8)]),
               np.concatenate([stem[:20],
                               rng.integers(0, cfg.vocab_size, size=9)]),
               np.concatenate([stem, rng.integers(0, cfg.vocab_size,
                                                  size=4)])]
    news = (4, 5, 6)
    warm, eng = _serve_staggered(cfg, params, prompts, news,
                                 prefix_chunk=8, check_invariants=True)
    cold, _ = _serve_staggered(cfg, params, prompts, news,
                               prefix_cache=False)
    assert warm == cold
    st = eng.prefix_stats()
    assert st["hits"] == 2          # request 2 (mid-page) and request 3
    # request 2 matched 16 of its 20 stem tokens: 1 full page (0 shared
    # full pages at page_size=16? 16//16 = 1) — and request 3 matched 24,
    # whose last 8 rows sit mid-page: at least one COW copy happened,
    # proven by parity + the surviving cache (invariants checked live)
    assert st["tokens_skipped"] == 16 + 24


def test_refcount_zero_reclaim_under_pressure():
    """A tiny pool stays serviceable indefinitely because pages recycle
    at refcount zero: slot releases AND LRU chain eviction both route
    through the same refcounted release; the engine never stalls."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(2)
    eng = Engine(cfg, params, num_slots=2, max_seq=64, num_pages=4,
                 check_invariants=True)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=20), 6)
            for _ in range(5)]
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.pool.slot_refs_total == 0
    # whatever is still in use is exactly the cache's retained pages
    assert eng.pages_in_use == eng.prefix.cached_pages
    assert eng.pages_high_water <= 4


def test_eviction_preferred_over_stall():
    """Pool dry with idle cached prefixes: admission must evict them (LRU)
    rather than stall — the big request completes and the eviction counter
    proves the path was taken."""
    cfg, params = _setup("gqa")
    eng = Engine(cfg, params, num_slots=2, max_seq=64, num_pages=4,
                 check_invariants=True)
    a = eng.submit(list(range(1, 30)), max_new_tokens=4)    # 2 pages
    eng.run()
    assert a.done and eng.prefix.cached_pages >= 1          # 1 page cached
    b = eng.submit(list(range(200, 250)), max_new_tokens=8)  # needs 4 pages
    eng.run()
    st = eng.prefix_stats()
    assert b.done and st["evictions"] >= 1


def test_failed_admission_eviction_does_not_leak():
    """An eviction round that still admits nothing (pool mostly held by a
    live request) must COMMIT its refcount decrements anyway: regression
    for the round-rollback bug where the registry dropped its chains but
    the -1 cache refs were discarded with the round — pages leaked as
    phantom-occupied forever, the queued request could never admit, and
    the I3 identity broke on the next sync."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, num_slots=2, max_seq=64, num_pages=6,
                 check_invariants=True)
    # a: long-lived, holds 3 of the 6 pages while the drama unfolds
    a = eng.submit(rng.integers(0, cfg.vocab_size, size=20), 25)
    # c: finishes fast, leaving cached chains (idle cache refs) behind
    c = eng.submit(rng.integers(0, cfg.vocab_size, size=17), 2)
    while not c.done:
        eng.step()
    assert not a.done and eng.prefix.cached_pages >= 1
    # b: no prefix match, needs 4 fresh pages; eviction frees the idle
    # cached pages but a's 3 still block admission -> round admits nothing
    b = eng.submit(rng.integers(0, cfg.vocab_size, size=50), 8)
    eng.step()          # invariants re-verified after the failed round
    assert eng.prefix.evictions >= 1 and not b.done
    eng.run()           # a drains, freeing its pages -> b must admit
    assert a.done and b.done
    assert eng.pool.slot_refs_total == 0
    assert eng.pages_in_use == eng.prefix.cached_pages
    # stats are committed per ADMISSION, not per planning retry: a, c and
    # b each count one miss however many rounds b waited in the queue
    assert eng.prefix.misses == 3 and eng.prefix.hits == 0


def test_registry_capacity_cap():
    """`prefix_max_chains` bounds the registry under high-cardinality
    traffic: registration evicts LRU chains past the cap (host memory
    stays finite without pool pressure), cache refs stay in lockstep with
    the device (invariants live), and serving is unaffected."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(6)
    eng = Engine(cfg, params, num_slots=2, max_seq=64,
                 prefix_max_chains=2, check_invariants=True)
    # 6 distinct 36-token prompts register 2 chains each (chunk=16)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=36), 4)
            for _ in range(6)]
    eng.run()
    assert all(r.done for r in reqs)
    assert len(eng.prefix.chains) <= 2
    assert eng.prefix.evictions >= 1
    assert eng.pool.slot_refs_total == 0
    assert eng.pages_in_use == eng.prefix.cached_pages


def test_high_water_strictly_below_cold_with_coresident_sharers():
    """4 co-resident requests sharing a 32-token prefix: pages-in-use
    high-water must be STRICTLY below 4x the cold per-request page count
    (the shared pages are stored once, not four times)."""
    cfg, params = _setup("gqa")
    rng = np.random.default_rng(3)
    sysp = rng.integers(0, cfg.vocab_size, size=32)
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab_size,
                                                  size=6)])
               for _ in range(4)]
    # per request: 38 prompt + 16 new - 1 = 53 rows -> 4 pages cold
    per_req = -(-(38 + 16 - 1) // cfg.page_size)

    def high_water(on):
        eng = Engine(cfg, params, num_slots=4, max_seq=64,
                     prefix_cache=on, check_invariants=True)
        first = eng.submit(prompts[0], 16)      # producer registers alone
        eng.step()
        rest = [eng.submit(p, 16) for p in prompts[1:]]
        eng.run()
        assert first.done and all(r.done for r in rest)
        streams = [first.out_tokens] + [r.out_tokens for r in rest]
        return eng.pages_high_water, streams

    hw_warm, s_warm = high_water(True)
    hw_cold, s_cold = high_water(False)
    assert s_warm == s_cold
    assert hw_cold == 4 * per_req          # cold: four private copies
    assert hw_warm < 4 * per_req           # warm: shared prefix stored once


def test_recurrent_hybrid_streams_identical_with_cache_flag():
    """The recurrent-hybrid arch ignores prefix_cache (state accumulates
    over all tokens) — flipping the flag changes nothing, not even pool
    occupancy accounting."""
    cfg, params = _setup("recurrent")
    rng = np.random.default_rng(4)
    sysp = rng.integers(0, cfg.vocab_size, size=12)
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab_size,
                                                  size=4)])
               for _ in range(2)]
    on, eng_on = _serve_staggered(cfg, params, prompts, (3, 3),
                                  check_invariants=True)
    off, eng_off = _serve_staggered(cfg, params, prompts, (3, 3),
                                    prefix_cache=False,
                                    check_invariants=True)
    assert on == off
    assert eng_on.prefix is None and eng_off.prefix is None
    assert eng_on.pages_in_use == eng_off.pages_in_use == 0
