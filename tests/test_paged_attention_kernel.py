"""Pallas paged-decode kernel vs the gather oracle (kernels/paged_attention).

The kernel walks each sequence's block table page by page (bounded by
`n_pages`, never `max_seq`); the oracle is the dense gather the serving
stack has always used (`paged_view` + `chunk_attention`, and
`decode_attention_q` for the int8 cache).  Everything here runs the real
kernel code in pallas interpret mode on CPU.

Covers: direct kernel/oracle parity at positions straddling page
boundaries (fp and int8), pools after a speculative-style rollback,
engine-level greedy stream bit-parity with the kernel on vs off across
{gqa, int8-KV} — including shared (prefix-cached, owned=False) pages —
and the free-slot (no pages) edge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import paged_attention as pk
from repro.models import attention as A
from repro.models import model as M
from repro.runtime import pages as pg
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")

PS = 16          # pool page size in these tests


def _pool_case(seed, B, n_pages, max_pages=4, P=16, Hkv=2, hd=16):
    """Random fp pool + per-sequence block tables (page ids shuffled so
    logical and physical order differ)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    k_pool = jax.random.normal(ks[0], (P, PS, Hkv, hd), jnp.float32)
    v_pool = jax.random.normal(ks[1], (P, PS, Hkv, hd), jnp.float32)
    tables = jax.random.permutation(
        ks[2], P)[:B * max_pages].reshape(B, max_pages).astype(jnp.int32)
    return k_pool, v_pool, tables, jnp.asarray(n_pages, jnp.int32)


def _bundle(tables, n_pages, max_seq):
    return A.PagedKV(tables=tables, n_pages=n_pages,
                     write_mask=jnp.ones(tables.shape[0], bool),
                     max_seq=max_seq, page_size=PS)


# --- direct kernel vs oracle ------------------------------------------------

@pytest.mark.parametrize("lengths", [(15, 16, 17), (1, 32, 33), (48, 2, 31)])
def test_kernel_matches_oracle_across_page_boundaries(lengths):
    """fp kernel output equals the gather oracle at live lengths below /
    at / across page boundaries (the page loop must include the partial
    tail page and exclude everything past it)."""
    B, H, max_seq = 3, 4, 64
    n_pages = [-(-n // PS) for n in lengths]
    k_pool, v_pool, tables, n_pages = _pool_case(0, B, n_pages)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, 1, H, 16), jnp.float32)
    positions = jnp.asarray(lengths, jnp.int32) - 1
    out = pk.paged_decode(q[:, 0], k_pool, v_pool, tables, n_pages,
                          positions + 1)
    pv = _bundle(tables, n_pages, max_seq)
    ref = A.chunk_attention(q, A.paged_view(k_pool, pv),
                            A.paged_view(v_pool, pv),
                            positions[:, None])[:, 0]
    np.testing.assert_allclose(out, ref, atol=2e-6)


def test_kernel_reads_only_allocated_pages():
    """Rows past a sequence's allocated pages must not contribute even
    when its stale table entries alias another sequence's live pages —
    poisoning every non-allocated page with huge values may not change
    the output."""
    B, H = 2, 4
    k_pool, v_pool, tables, n_pages = _pool_case(1, B, [1, 2])
    q = jax.random.normal(jax.random.PRNGKey(3), (B, H, 16), jnp.float32)
    lengths = jnp.asarray([PS, 2 * PS], jnp.int32)
    out = pk.paged_decode(q, k_pool, v_pool, tables, n_pages, lengths)
    # poison every page no sequence legitimately reads
    live = np.zeros(k_pool.shape[0], bool)
    tb = np.asarray(tables)
    for b, n in enumerate(np.asarray(n_pages)):
        live[tb[b, :n]] = True
    k_bad = jnp.where(jnp.asarray(live)[:, None, None, None], k_pool, 1e9)
    v_bad = jnp.where(jnp.asarray(live)[:, None, None, None], v_pool, 1e9)
    out_bad = pk.paged_decode(q, k_bad, v_bad, tables, n_pages, lengths)
    np.testing.assert_array_equal(out, out_bad)


def test_kernel_free_slot_emits_zeros():
    """A slot with no pages (released / never admitted) reads nothing and
    returns exact zeros instead of NaN from an empty softmax."""
    k_pool, v_pool, tables, n_pages = _pool_case(2, 2, [0, 2])
    q = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 16), jnp.float32)
    out = pk.paged_decode(q, k_pool, v_pool, tables, n_pages,
                          jnp.asarray([1, 20], jnp.int32))
    assert bool(jnp.all(out[0] == 0.0))
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("lengths", [(15, 16, 17), (1, 33, 48)])
def test_kernel_int8_matches_oracle(lengths):
    """int8 variant replays decode_attention_q's arithmetic (including the
    probability requantization) — outputs agree to reassociation error."""
    B, H, max_seq = 3, 4, 64
    n_pages = [-(-n // PS) for n in lengths]
    k_pool, v_pool, tables, n_pages = _pool_case(7, B, n_pages)
    kq, kss = A._quant_rows(k_pool)
    vq, vss = A._quant_rows(v_pool)
    q = jax.random.normal(jax.random.PRNGKey(11), (B, 1, H, 16), jnp.float32)
    positions = jnp.asarray(lengths, jnp.int32) - 1
    qq, qs = A._quant_rows(q)
    out = pk.paged_decode_q(qq[:, 0], qs[:, 0], kq, kss, vq, vss, tables,
                            n_pages, positions + 1, q.dtype)
    pv = _bundle(tables, n_pages, max_seq)
    cache = {"k": kq, "ks": kss, "v": vq, "vs": vss}
    view = {key: A.paged_view(cache[key], pv) for key in cache}
    ref = A.decode_attention_q(q, view, positions[:, None])[:, 0]
    np.testing.assert_allclose(out, ref, atol=1e-6)


def test_kernel_after_rollback_matches_oracle():
    """Speculative-style pool: a draft window is written through
    paged_update, the verify pass rejects its tail, pages.rollback zeroes
    the rejected rows — the kernel must read the exact post-rollback pool
    the oracle reads."""
    B, Hkv, hd, max_seq = 2, 2, 16, 64
    k_pool, v_pool, tables, n_pages = _pool_case(13, B, [2, 2])
    pv = _bundle(tables, n_pages, max_seq)
    # draft window of 4 rows at positions 20..23 / 10..13, bound mid-window
    window = jnp.stack([jnp.arange(20, 24), jnp.arange(10, 14)]).astype(
        jnp.int32)
    pvw = A.PagedKV(tables=pv.tables, n_pages=pv.n_pages,
                    write_mask=pv.write_mask, max_seq=max_seq, page_size=PS,
                    bound=jnp.asarray([24, 14], jnp.int32))
    new_k = jax.random.normal(jax.random.PRNGKey(17), (B, 4, Hkv, hd))
    new_v = jax.random.normal(jax.random.PRNGKey(19), (B, 4, Hkv, hd))
    k_pool = A.paged_update(k_pool, new_k, window, pvw)
    v_pool = A.paged_update(v_pool, new_v, window, pvw)
    # verify accepted 1 row for slot 0, 2 rows for slot 1: reject the rest
    rejected = jnp.asarray([[21, 22, 23, max_seq],
                            [12, 13, max_seq, max_seq]], jnp.int32)
    # rollback operates on stacked (n_periods, P, ps, ...) cache leaves
    caches = pg.rollback({"k": k_pool[None], "v": v_pool[None]},
                         {"k": True, "v": True}, pvw, rejected)
    k_pool, v_pool = caches["k"][0], caches["v"][0]
    q = jax.random.normal(jax.random.PRNGKey(23), (B, 1, 4, hd), jnp.float32)
    positions = jnp.asarray([21, 12], jnp.int32)   # last accepted row
    out = pk.paged_decode(q[:, 0], k_pool, v_pool, tables,
                          n_pages, positions + 1)
    ref = A.chunk_attention(q, A.paged_view(k_pool, pv),
                            A.paged_view(v_pool, pv),
                            positions[:, None])[:, 0]
    np.testing.assert_allclose(out, ref, atol=2e-6)


# --- engine-level greedy stream parity --------------------------------------

@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b", smoke=True)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _streams(cfg, params, prompts, *, decode_kernel, **kw):
    with Engine(cfg, params, num_slots=3, max_seq=64, decode_steps=4,
                decode_kernel=decode_kernel, **kw) as eng:
        reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
        eng.run()
        return [tuple(r.out_tokens) for r in reqs]


# prompt lengths below / at / across the page_size=16 boundary, plus a
# long one that spans three pages mid-stream
PROMPTS = (15, 16, 17, 33, 5)


@pytest.mark.parametrize("quant_kv", [False, True],
                         ids=["gqa", "int8-kv"])
def test_engine_streams_bit_identical(granite, quant_kv):
    """Greedy decode through the pallas kernel emits bit-identical token
    streams to the gather oracle, fp and int8-KV alike, with prompts
    straddling page boundaries and slot contention (5 requests, 3 slots)."""
    cfg, params = granite
    if quant_kv:
        cfg = cfg.replace(quant_kv=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in PROMPTS]
    on = _streams(cfg, params, prompts, decode_kernel=True)
    off = _streams(cfg, params, prompts, decode_kernel=False)
    assert on == off


def test_engine_streams_shared_prefix_pages(granite):
    """Warm prefix-cache admissions map pages read-only (owned=False) into
    the sharers' tables; the kernel reads them through the block table
    exactly as the oracle gathers them — streams stay bit-identical."""
    cfg, params = granite
    rng = np.random.default_rng(1)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=32).tolist()
    prompts = [sys_prompt + rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (3, 7, 11)]

    def run(decode_kernel):
        with Engine(cfg, params, num_slots=3, max_seq=64, decode_steps=2,
                    decode_kernel=decode_kernel, prefix_cache=True) as eng:
            warm = eng.submit(sys_prompt, max_new_tokens=4)   # registers
            eng.run()
            reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
            eng.run()
            assert eng.pages_shared_high_water > 0, \
                "prefix shares never happened — test is vacuous"
            return [tuple(r.out_tokens) for r in (warm, *reqs)]

    assert run(True) == run(False)


def test_engine_kv_bytes_scale_with_live_tokens(granite):
    """The engine's per-step KV read accounting: under the kernel, bytes
    track live tokens and sit strictly below the gather oracle's
    num_slots*max_seq floor for short sequences."""
    cfg, params = granite
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).tolist()
               for _ in range(2)]
    per_step = {}
    for dk in (True, False):
        with Engine(cfg, params, num_slots=4, max_seq=64, decode_steps=2,
                    decode_kernel=dk) as eng:
            for p in prompts:
                eng.submit(p, max_new_tokens=8)
            eng.run()
            per_step[dk] = eng.kv_bytes_read / eng.kv_read_steps
    oracle_rows = pk.oracle_read_rows(4, 64)
    assert per_step[False] == oracle_rows * pk.kv_row_bytes(cfg)
    assert per_step[True] < per_step[False]
