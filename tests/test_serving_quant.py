"""Serving-time quantized execution: pre-quantized parameter trees flow
through jit, shard rules, and produce outputs close to fp."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.core.quant import QuantizedTensor
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")

QCFG8 = bl.QuantConfig(enabled=True, bits_w=8, bits_a=8)
QCFG4 = bl.QuantConfig(enabled=True, bits_w=4, bits_a=4)


def test_tree_prepare_serving_selects_right_leaves():
    cfg = get_config("dbrx-132b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qparams = bl.tree_prepare_serving(params, QCFG8)
    # embedding/router stay float, projections + experts become QT
    assert not isinstance(qparams["embed"]["embedding"], QuantizedTensor)
    assert isinstance(qparams["embed"]["unembed"], QuantizedTensor)
    layer = qparams["layers"]["pos0"]
    assert isinstance(layer["mixer"]["wq"], QuantizedTensor)
    assert not isinstance(layer["moe"]["router"], QuantizedTensor)
    assert isinstance(layer["moe"]["w_gate"], QuantizedTensor)
    assert layer["moe"]["w_gate"].values.ndim == 4   # (periods, E, d, f)


def test_int4_weights_are_packed():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    qt = bl.prepare_serving(w, QCFG4)
    # packed along the contraction axis (-2): half the bytes
    assert qt.packed and qt.values.shape == (16, 16)
    deq = qt.dequantize()
    assert float(jnp.max(jnp.abs(deq - w))) < float(jnp.max(jnp.abs(w)))


@pytest.mark.parametrize("arch", ["granite-8b", "dbrx-132b", "minicpm3-4b"])
def test_quantized_forward_close_to_fp(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    fp, _, _ = M.forward(params, {"tokens": tokens}, cfg)
    qparams = bl.tree_prepare_serving(params, QCFG8)
    q, _, _ = jax.jit(lambda p, b: M.forward(p, b, cfg))(
        qparams, {"tokens": tokens})
    cos = float(jnp.sum(fp * q) / (jnp.linalg.norm(fp) * jnp.linalg.norm(q)))
    assert cos > 0.99, (arch, cos)


def test_quantized_decode_roundtrip():
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qparams = bl.tree_prepare_serving(params, QCFG8)
    caches = M.init_cache(cfg, 2, 16)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    _, caches = M.prefill(qparams, {"tokens": tokens}, cfg, caches)
    pos = jnp.full((2,), 8, jnp.int32)
    logits, _ = M.decode_step(qparams, tokens[:, :1], cfg, caches, pos)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_engine_uids_unique_after_queue_drain():
    """Regression: uid was `len(queue)`, so ids recycled once the queue
    drained and two live requests could alias.  Now a monotonic counter."""
    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from repro.runtime.serve import Engine
    eng = Engine(cfg, params, num_slots=2, max_seq=32)
    r1 = eng.submit([1, 2, 3], max_new_tokens=2)
    r2 = eng.submit([4, 5], max_new_tokens=2)
    eng.run()
    assert r1.done and r2.done
    r3 = eng.submit([6, 7], max_new_tokens=2)   # queue drained before this
    assert len({r1.uid, r2.uid, r3.uid}) == 3
    eng.run()
    assert r3.done


def test_engine_threads_capacity_factor_and_dispatch():
    """Engine(capacity_factor=..., dispatch=...) overrides the MoE routing
    knobs on cfg BEFORE any tracing, so the jit'd prefill/decode close over
    them — and the continuous-batching loop still completes on a quantized
    MoE arch with the lossy per-source dispatch requested."""
    from repro.runtime.serve import Engine

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qparams = bl.tree_prepare_serving(params, QCFG8)
    with Engine(cfg, qparams, num_slots=2, max_seq=32,
                capacity_factor=2.0, dispatch="per_source") as eng:
        assert eng.cfg.moe_capacity_factor == 2.0
        assert eng.cfg.ep_dispatch == "per_source"
        assert cfg.moe_capacity_factor == 1.25  # caller's cfg untouched
        reqs = [eng.submit([1, 2, 3], max_new_tokens=3),
                eng.submit([4, 5], max_new_tokens=3)]
        eng.run()
    assert all(r.done and len(r.out_tokens) == 3 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)
    with pytest.raises(ValueError, match="dispatch"):
        Engine(cfg, qparams, num_slots=1, max_seq=8, dispatch="bogus")


def test_serve_einsum_edf_matches_float():
    rng = np.random.default_rng(0)
    E, C, d, f = 4, 8, 32, 16
    x = jnp.asarray(rng.normal(size=(E, C, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32))
    qw = bl.prepare_serving(w, QCFG8)
    got = bl.serve_einsum_edf(x, qw, transpose_out=False)
    want = jnp.einsum("ecd,edf->ecf", x, w)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02, rel
