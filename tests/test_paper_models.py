"""Validation of the cycle/throughput/area models against the paper's claims."""
import math

import pytest

from repro.core import arch_models as am
from repro.core import gemv_model as gm
from repro.core.efsm import BRAMAC_1DA, BRAMAC_2SA


# --- Table II -------------------------------------------------------------

def test_mac2_latencies_exact():
    assert [BRAMAC_2SA.mac2_latency(b) for b in (2, 4, 8)] == [5, 7, 11]
    assert [BRAMAC_1DA.mac2_latency(b) for b in (2, 4, 8)] == [3, 4, 6]


def test_macs_in_parallel_exact():
    assert [BRAMAC_2SA.macs_in_parallel(b) for b in (2, 4, 8)] == [80, 40, 20]
    assert [BRAMAC_1DA.macs_in_parallel(b) for b in (2, 4, 8)] == [40, 20, 10]


def test_max_dot_product_sizes_exact():
    # §IV-C: 16/256/2048 MACs before accumulator readout
    for v in (BRAMAC_2SA, BRAMAC_1DA):
        assert [v.max_dot_product_macs(b) for b in (2, 4, 8)] == [16, 256, 2048]


def test_readout_busy_cycles_exact():
    assert BRAMAC_2SA.readout_busy_cycles() == 8
    assert BRAMAC_1DA.readout_busy_cycles() == 4


def test_port_busy_cycles():
    assert BRAMAC_2SA.port_busy_per_mac2 == 2
    assert BRAMAC_1DA.port_busy_per_mac2 == 1


# --- Fig 9 ----------------------------------------------------------------

PAPER_BOOSTS = {(BRAMAC_2SA.name, 2): 2.6, (BRAMAC_2SA.name, 4): 2.3,
                (BRAMAC_2SA.name, 8): 1.9, (BRAMAC_1DA.name, 2): 2.1,
                (BRAMAC_1DA.name, 4): 2.0, (BRAMAC_1DA.name, 8): 1.7}


@pytest.mark.parametrize("variant", [BRAMAC_2SA, BRAMAC_1DA])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_peak_throughput_boosts(variant, bits):
    got = am.throughput_boost(bits, variant)
    want = PAPER_BOOSTS[(variant.name, bits)]
    assert abs(got - want) / want < 0.05, (got, want)


def test_bramac_outperforms_ccb_comefa():
    """Fig 9: BRAMAC throughput > CCB/CoMeFa at every precision."""
    for bits in (2, 4, 8):
        b2 = am.bram_throughput(BRAMAC_2SA, bits)
        b1 = am.bram_throughput(BRAMAC_1DA, bits)
        for rival in (am.CCB, am.COMEFA_D, am.COMEFA_A):
            assert b2 > am.bram_throughput(rival, bits)
            assert b1 > am.bram_throughput(rival, bits)


# --- Fig 10 ---------------------------------------------------------------

def test_utilization_bramac_100pct_at_supported():
    for p in (2, 4, 8):
        assert am.bramac_utilization(p) == 1.0


def test_utilization_advantage():
    adv = am.utilization_advantage()
    assert abs(adv["vs_ccb"] - 1.3) < 0.12        # paper: 1.3x
    assert abs(adv["vs_comefa"] - 1.1) < 0.08     # paper: 1.1x


# --- Fig 7 ----------------------------------------------------------------

def test_adder_study():
    d_rca = am.adder_delay_ps("RCA", 32)
    d_cba = am.adder_delay_ps("CBA", 32)
    d_cla = am.adder_delay_ps("CLA", 32)
    assert abs(d_rca / d_cba - 2.8) < 0.05        # paper: 2.8x
    assert abs(d_rca / d_cla - 2.5) < 0.05        # paper: 2.5x
    # CLA chosen: fastest-but-power-hungry CBA vs slow RCA trade-off
    assert am.ADDERS["CBA"]["power_uw"] > am.ADDERS["CLA"]["power_uw"]
    assert am.ADDERS["CLA"]["power_uw"] > am.ADDERS["RCA"]["power_uw"]


# --- Fig 11 ---------------------------------------------------------------

PAPER_GEMV = {("persistent", 2): 3.3, ("persistent", 4): 2.8,
              ("persistent", 8): 2.4, ("nonpersistent", 2): 4.1,
              ("nonpersistent", 4): 3.4, ("nonpersistent", 8): 2.8}


def test_gemv_max_speedups():
    got = gm.max_speedups()
    for key, want in PAPER_GEMV.items():
        assert abs(got[key] - want) / want < 0.15, (key, got[key], want)


def test_gemv_trends():
    # speedup decreases with precision (paper §VI-C)
    for persistent in (True, False):
        tag = "persistent" if persistent else "nonpersistent"
        ms = gm.max_speedups()
        assert ms[(tag, 2)] > ms[(tag, 4)] > ms[(tag, 8)]
    # non-persistent > persistent at same precision (eFSM tiling advantage)
    ms = gm.max_speedups()
    for b in (2, 4, 8):
        assert ms[("nonpersistent", b)] > ms[("persistent", b)]
    # vectorization efficiency: R=160 (perfect) beats R=64 (80%) at 2-bit
    g = gm.speedup_grid(2, True)
    assert g[(160, 128)] > g[(64, 128)]
    # packing: CCB amortizes reductions at large C → lower speedup at C=480
    g8 = gm.speedup_grid(8, False)
    assert g8[(160, 128)] > g8[(160, 480)]


def test_bramac_gemv_cycle_structure():
    c = gm.bramac_gemv(BRAMAC_1DA, 160, 128, 4)
    # 16 tiles x (64 MAC2 x 4 cycles + 1 drain x 4) + 2 initial copy cycles
    assert c.total_persistent == 16 * (64 * 4 + 4) + 2
    assert c.load == math.ceil(160 * 128 * 4 / 40)


# --- Fig 13 / Table III ----------------------------------------------------

@pytest.fixture(scope="module")
def dla_results():
    from repro.core.dla_model import average_speedups, case_study
    res = case_study()
    return res, average_speedups(res)


def test_dla_speedup_ranges(dla_results):
    _, avg = dla_results
    # paper: AlexNet 2.05x/1.7x; ResNet-34 1.33x/1.52x.  Our DSE model
    # reproduces AlexNet and ResNet-1DA within ~12%; ResNet-2SA finds a
    # stronger configuration than the paper's (see EXPERIMENTS.md §Fig13).
    assert abs(avg[("alexnet", "BRAMAC-2SA")]["speedup"] - 2.05) < 0.25
    assert abs(avg[("alexnet", "BRAMAC-1DA")]["speedup"] - 1.70) < 0.25
    assert abs(avg[("resnet34", "BRAMAC-1DA")]["speedup"] - 1.52) < 0.25
    assert avg[("resnet34", "BRAMAC-2SA")]["speedup"] > 1.33


def test_dla_dsp_formula_matches_table3():
    """The DSP model reproduces Table III's DSP counts exactly."""
    from repro.core.dla_model import dsp_count
    # (qvec1, cvec, kvec, bits) -> DSPs from Table III
    rows = [((2, 16, 96), 2, 1152), ((3, 16, 32), 4, 1152),
            ((3, 12, 24), 8, 1296), ((4, 12, 72), 2, 1296),
            ((3, 8, 64), 4, 1152), ((3, 4, 64), 8, 1152),
            ((1, 24, 140), 2, 1260), ((1, 16, 100), 4, 1200),
            ((2, 10, 50), 8, 1500), ((2, 16, 100), 2, 1200)]
    for (q, c, k), bits, want in rows:
        assert dsp_count(q, c, k, bits) == want, (q, c, k, bits)


def test_dla_resource_budgets(dla_results):
    res, _ = dla_results
    for row in res.values():
        for name, (cfg, stats) in row.items():
            assert stats["dsps"] <= 1518
            assert stats["brams"] <= 2423


def test_dla_bramac_perf_per_area_gain(dla_results):
    """Fig 13c: DLA-BRAMAC gains performance per utilized area (>= ~1x)."""
    res, _ = dla_results
    for (model, bits), row in res.items():
        for vname in ("BRAMAC-2SA", "BRAMAC-1DA"):
            assert row[vname][1]["perf_per_area"] > 0.95, (model, bits, vname)
