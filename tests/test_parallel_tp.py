"""Tensor-parallel quantized execution on 8 virtual CPU devices (the
`multidevice` marker — see tests/conftest.py), plus unit tests for the
version-portable shard_map compat layer."""
import jax
import pytest
from conftest import run_multidevice as run_sub

from repro.parallel import compat


@pytest.mark.multidevice
def test_tp_quant_matmul_bit_exact_all_bits():
    """K-sharded (int32 partial psum) and N-sharded (column-parallel) TP
    matmul == single-device quant_matmul, bit for bit, for 2/4/8-bit."""
    out = run_sub("""
from repro.core.quant import qrange
from repro.kernels import ops
from repro.parallel import tp

mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
M, K, N = 16, 64, 24
for bits_a in (2, 4, 8):
    for bits_w in (2, 4, 8):
        la, ha = qrange(bits_a)
        lw, hw = qrange(bits_w)
        xq = jnp.asarray(rng.integers(la, ha + 1, (M, K), dtype=np.int8))
        wq = jnp.asarray(rng.integers(lw, hw + 1, (K, N), dtype=np.int8))
        xs = jnp.asarray(rng.uniform(0.5, 2.0, (M, 1)).astype(np.float32))
        ws = jnp.asarray(rng.uniform(0.5, 2.0, (1, N)).astype(np.float32))
        ref = ops.quant_matmul(xq, wq, xs, ws, bits_a=bits_a, bits_w=bits_w)
        for part in ("k", "n"):
            got = tp.tp_quant_matmul(xq, wq, xs, ws, mesh=mesh,
                                     bits_a=bits_a, bits_w=bits_w,
                                     partition=part)
            assert got.dtype == ref.dtype
            assert bool(jnp.all(got == ref)), (bits_a, bits_w, part)
print("TP_EXACT_OK")
""")
    assert "TP_EXACT_OK" in out


@pytest.mark.multidevice
def test_tp_quant_matmul_respects_active_tp_rule():
    """With a sharding ctx active, tp resolves the physical axis from the
    logical `tp` rule instead of assuming an axis name."""
    out = run_sub("""
from repro.core.quant import qrange
from repro.kernels import ops
from repro.parallel import sharding as shd, tp

mesh = jax.make_mesh((2, 4), ("data", "model"))
shd.activate(mesh)                       # rules: tp -> "model"
rng = np.random.default_rng(1)
lo, hi = qrange(8)
xq = jnp.asarray(rng.integers(lo, hi + 1, (8, 32), dtype=np.int8))
wq = jnp.asarray(rng.integers(lo, hi + 1, (32, 16), dtype=np.int8))
one = jnp.ones((1, 1), jnp.float32)
ref = ops.quant_matmul(xq, wq, one, one, bits_a=8, bits_w=8)
got = tp.tp_quant_matmul(xq, wq, one, one, mesh=mesh, bits_a=8, bits_w=8)
assert bool(jnp.all(got == ref))
print("TP_RULE_OK")
""")
    assert "TP_RULE_OK" in out


@pytest.mark.multidevice
def test_tp_quant_matmul_divisibility_error():
    out = run_sub("""
from repro.parallel import tp

mesh = jax.make_mesh((8,), ("model",))
x = jnp.zeros((4, 12), jnp.int8)         # K=12 not divisible by 8
w = jnp.zeros((12, 8), jnp.int8)
one = jnp.ones((1, 1), jnp.float32)
try:
    tp.tp_quant_matmul(x, w, one, one, mesh=mesh, bits_a=8, bits_w=8)
except ValueError as e:
    assert "not divisible" in str(e)
    print("TP_DIV_OK")
""")
    assert "TP_DIV_OK" in out


@pytest.mark.multidevice
def test_sharded_quantized_engine_decode():
    """Engine(mesh=...) with a pre-quantized parameter tree: the full
    continuous-batching loop (prefill + decode) completes tensor-parallel."""
    out = run_sub("""
from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.models import model as M
from repro.runtime.serve import Engine

cfg = get_config("granite-8b", smoke=True)
params = M.init_params(cfg, jax.random.PRNGKey(0))
qparams = bl.tree_prepare_serving(
    params, bl.QuantConfig(enabled=True, bits_w=8, bits_a=8))
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng = Engine(cfg, qparams, num_slots=2, max_seq=32, mesh=mesh)
reqs = [eng.submit([1, 2, 3], max_new_tokens=3),
        eng.submit([4, 5], max_new_tokens=3)]
eng.run()
assert all(r.done for r in reqs)
assert all(len(r.out_tokens) == 3 for r in reqs)
assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)
print("ENGINE_TP_OK")
""")
    assert "ENGINE_TP_OK" in out


# ---------------------------------------------------------------------------
# compat shim units (in-process: a 1-device mesh needs no XLA flag)
# ---------------------------------------------------------------------------

def test_compat_shard_map_runs_with_either_flag_spelling():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.arange(4.0)
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        f = compat.shard_map(lambda a: a * 2, mesh=mesh, in_specs=P("d"),
                             out_specs=P("d"), **kw)
        assert jnp.all(f(x) == x * 2)


def test_compat_shard_map_conflicting_flags_raise():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("d",))
    with pytest.raises(ValueError, match="aliases"):
        compat.shard_map(lambda a: a, mesh=mesh, in_specs=P("d"),
                         out_specs=P("d"), check_vma=True, check_rep=False)


def test_compat_translates_to_installed_spelling():
    """The kwarg actually forwarded must be one the installed JAX accepts."""
    impl, params = compat._impl()
    has_new = "check_vma" in params
    has_old = "check_rep" in params
    assert has_new or has_old or params == frozenset()
    # and the public entry accepted *both* spellings above regardless
