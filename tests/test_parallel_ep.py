"""Expert-parallel quantized execution on 8 virtual CPU devices (the
`multidevice` marker — see tests/conftest.py), plus in-process unit tests
for the DP×TP(×EP) mesh-spec builder."""
import jax
import pytest
from conftest import run_multidevice as run_sub

from repro.parallel import sharding as shd


@pytest.mark.multidevice
def test_ep_quant_einsum_bit_exact_all_bits():
    """Expert-sharded and contraction-sharded (int32 psum) expert einsum ==
    single-device serve_einsum_edf, bit for bit, for 2/4/8-bit weights."""
    out = run_sub("""
from repro.core import bramac_linear as bl
from repro.parallel import ep, sharding as shd

mesh = shd.build_mesh("model=8")
rng = np.random.default_rng(0)
E, C, d, f = 8, 16, 32, 24
x = jnp.asarray(rng.normal(size=(E, C, d)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32))
for bits in (2, 4, 8):
    qw = bl.prepare_serving(w, bl.QuantConfig(enabled=True, bits_w=bits))
    ref = bl.serve_einsum_edf(x, qw, transpose_out=False)
    for part in ("e", "d"):
        got = ep.ep_quant_einsum_edf(x, qw, mesh=mesh, partition=part)
        assert got.dtype == ref.dtype
        assert bool(jnp.all(got == ref)), (bits, part)
print("EP_EXACT_OK")
""")
    assert "EP_EXACT_OK" in out


@pytest.mark.multidevice
def test_ep_quant_einsum_dp_composition():
    """DP×EP and DP×TP on a (2 data × 4 model) mesh: the capacity axis
    rides the data axis, experts/contraction the model axis — still
    bit-exact (capacity rows are independent; contraction partials meet in
    int32)."""
    out = run_sub("""
from repro.core import bramac_linear as bl
from repro.parallel import ep, sharding as shd

mesh = shd.build_mesh("data=2,model=4")
assert mesh.shape == {"data": 2, "model": 4}
rng = np.random.default_rng(1)
E, C, d, f = 8, 16, 32, 24
x = jnp.asarray(rng.normal(size=(E, C, d)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32))
qw = bl.prepare_serving(w, bl.QuantConfig(enabled=True, bits_w=4))
ref = bl.serve_einsum_edf(x, qw, transpose_out=False)
for part in ("e", "d"):
    got = ep.ep_quant_einsum_edf(x, qw, mesh=mesh, partition=part,
                                 dp_axis="data")
    assert bool(jnp.all(got == ref)), part
print("EP_DP_OK")
""")
    assert "EP_DP_OK" in out


@pytest.mark.multidevice
def test_ep_quant_einsum_divisibility_error():
    out = run_sub("""
from repro.core import bramac_linear as bl
from repro.parallel import ep, sharding as shd

mesh = shd.build_mesh("model=8")
x = jnp.zeros((6, 4, 16), jnp.float32)   # E=6 not divisible by 8
w = jnp.zeros((6, 16, 8), jnp.float32)
qw = bl.prepare_serving(w, bl.QuantConfig(enabled=True, bits_w=8))
try:
    ep.ep_quant_einsum_edf(x, qw, mesh=mesh, partition="e")
except ValueError as e:
    assert "not divisible" in str(e)
    print("EP_DIV_OK")
""")
    assert "EP_DIV_OK" in out


@pytest.mark.multidevice
def test_ep_moe_bit_exact_vs_single_device():
    """ep_moe (all_to_all dispatch / all_gather combine, global-rank
    recovery) == the single-device moe() quantized path bit for bit,
    2/4/8-bit, both at no-drop capacity AND with capacity-overflow
    drops."""
    out = run_sub("""
from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.models import moe as moe_mod
from repro.parallel import ep, sharding as shd

mesh = shd.build_mesh("model=8")
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)   # E=8, top-2
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                      jnp.float32)
for bits in (2, 4, 8):
    qp = bl.tree_prepare_serving(
        p, bl.QuantConfig(enabled=True, bits_w=bits, bits_a=8))
    for cf in (cfg.num_experts / cfg.experts_per_token, 1.0):
        ref, aux_ref = moe_mod.moe(qp, x, cfg, capacity_factor=cf)
        got, aux = ep.ep_moe(qp, x, cfg, mesh=mesh, capacity_factor=cf)
        assert bool(jnp.all(got == ref)), (bits, cf)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
# float (training) weights go through the plain-einsum branch
ref, _ = moe_mod.moe(p, x, cfg, capacity_factor=4.0)
got, _ = ep.ep_moe(p, x, cfg, mesh=mesh, capacity_factor=4.0)
assert bool(jnp.all(got == ref))
print("EP_MOE_OK")
""")
    assert "EP_MOE_OK" in out


@pytest.mark.multidevice
def test_ep_moe_per_source_no_drop_bit_exact():
    """GShard per-source-capacity dispatch == single-device moe() bit for
    bit at no-drop capacity (cf = E/k ⇒ C_src = T_local, nothing ever
    overflows a shard-local buffer), for 2/4/8-bit AND float weights."""
    out = run_sub("""
from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.models import moe as moe_mod
from repro.parallel import ep, sharding as shd

mesh = shd.build_mesh("model=8")
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)   # E=8, top-2
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                      jnp.float32)
cf = cfg.num_experts / cfg.experts_per_token
for bits in (2, 4, 8):
    qp = bl.tree_prepare_serving(
        p, bl.QuantConfig(enabled=True, bits_w=bits, bits_a=8))
    ref, aux_ref = moe_mod.moe(qp, x, cfg, capacity_factor=cf)
    got, aux, keep = ep.ep_moe(qp, x, cfg, mesh=mesh, capacity_factor=cf,
                               dispatch="per_source", return_drops=True)
    assert bool(jnp.all(keep)), bits                 # truly no drops
    assert bool(jnp.all(got == ref)), bits
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
ref, _ = moe_mod.moe(p, x, cfg, capacity_factor=cf)
got, _ = ep.ep_moe(p, x, cfg, mesh=mesh, capacity_factor=cf,
                   dispatch="per_source")
assert bool(jnp.all(got == ref))
print("EP_PS_NODROP_OK")
""")
    assert "EP_PS_NODROP_OK" in out


@pytest.mark.multidevice
def test_ep_moe_per_source_matches_reference_tight_capacity():
    """At tight capacity the lossy per-source path == the single-device
    `ep.per_source_reference` simulator bit for bit — values AND the drop
    mask — for 2/4/8-bit; and it genuinely drops (≠ the global path)."""
    out = run_sub("""
from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.models import moe as moe_mod
from repro.parallel import ep, sharding as shd

mesh = shd.build_mesh("model=8")
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                      jnp.float32)
for bits in (2, 4, 8):
    qp = bl.tree_prepare_serving(
        p, bl.QuantConfig(enabled=True, bits_w=bits, bits_a=8))
    got, aux, keep = ep.ep_moe(qp, x, cfg, mesh=mesh, capacity_factor=1.0,
                               dispatch="per_source", return_drops=True)
    want, aux_ref, keep_ref = ep.per_source_reference(
        qp, x, cfg, ep_size=8, capacity_factor=1.0)
    assert bool(jnp.all(keep == keep_ref)), bits
    assert bool(jnp.all(got == want)), bits
    assert not bool(jnp.all(keep)), bits             # tight cf does drop
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
    exact, _ = ep.ep_moe(qp, x, cfg, mesh=mesh, capacity_factor=1.0,
                         dispatch="global")
    assert not bool(jnp.all(got == exact)), bits     # lossy != exact
print("EP_PS_TIGHT_OK")
""")
    assert "EP_PS_TIGHT_OK" in out


@pytest.mark.multidevice
def test_moe_routes_through_ep_when_mesh_active():
    """With a sharding ctx active, moe()'s quantized expert compute routes
    through the expert-parallel shard_map einsum — same bits out."""
    out = run_sub("""
from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.models import moe as moe_mod
from repro.parallel import sharding as shd

cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg)
qp = bl.tree_prepare_serving(
    p, bl.QuantConfig(enabled=True, bits_w=8, bits_a=8))
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                      jnp.float32)
ref, _ = moe_mod.moe(qp, x, cfg)
shd.activate(shd.build_mesh("data=2,model=4"))
try:
    got, _ = moe_mod.moe(qp, x, cfg)
finally:
    shd.deactivate()
assert bool(jnp.all(got == ref))
print("EP_ROUTE_OK")
""")
    assert "EP_ROUTE_OK" in out


@pytest.mark.multidevice
def test_ep_engine_decode_composed_mesh():
    """Engine with a composed DP×TP mesh *spec* on a quantized MoE arch:
    the continuous-batching loop completes with expert compute running
    through the EP shard_map path inside jit'd prefill/decode."""
    out = run_sub("""
from repro.configs import get_config
from repro.core import bramac_linear as bl
from repro.models import model as M
from repro.runtime.serve import Engine

cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
params = M.init_params(cfg, jax.random.PRNGKey(0))
qparams = bl.tree_prepare_serving(
    params, bl.QuantConfig(enabled=True, bits_w=8, bits_a=8))
eng = Engine(cfg, qparams, num_slots=2, max_seq=32, mesh="data=2,model=4")
assert eng.mesh.shape == {"data": 2, "model": 4}
reqs = [eng.submit([1, 2, 3], max_new_tokens=3),
        eng.submit([4, 5], max_new_tokens=3)]
eng.run()
eng.close()
assert all(r.done and len(r.out_tokens) == 3 for r in reqs)
assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)
print("ENGINE_EP_OK")
""")
    assert "ENGINE_EP_OK" in out


# ---------------------------------------------------------------------------
# in-process: capacity_factor forwarding (regression) — a 1-device mesh is
# enough to activate the EP route, so this runs in the plain pytest pass
# ---------------------------------------------------------------------------

def test_moe_forwards_capacity_factor_to_ep_route():
    """Regression: when moe() hands the layer to ep.ep_moe (per-source
    dispatch under an active ctx), it must reuse the CALLER's
    capacity_factor — not ep_moe's own default — or the sharded and dense
    paths silently disagree on what gets dropped."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import bramac_linear as bl
    from repro.models import moe as moe_mod
    from repro.parallel import ep

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True).replace(
        ep_dispatch="per_source")
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    qp = bl.tree_prepare_serving(
        p, bl.QuantConfig(enabled=True, bits_w=8, bits_a=8))
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    shd.activate(shd.build_mesh("model=1"))
    try:
        got, _ = moe_mod.moe(qp, x, cfg, capacity_factor=0.5)
    finally:
        shd.deactivate()
    want, _, keep = ep.per_source_reference(qp, x, cfg, ep_size=1,
                                            capacity_factor=0.5)
    assert bool(jnp.all(got == want))
    assert not bool(jnp.all(keep))          # tight cf really dropped
    # the forwarded cf must have MATTERED (ep_moe's default would differ)
    bad, _, _ = ep.per_source_reference(qp, x, cfg, ep_size=1,
                                        capacity_factor=1.25)
    assert not bool(jnp.all(got == bad))
    # and with no ctx, per_source falls through to the dense path, which
    # is per-source semantics at ep_size=1 — same bits
    dense, _ = moe_mod.moe(qp, x, cfg, capacity_factor=0.5)
    assert bool(jnp.all(dense == want))


def test_moe_rejects_unknown_dispatch():
    from repro.configs import get_config
    from repro.models import moe as moe_mod

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    x = jax.numpy.zeros((1, 8, cfg.d_model), jax.numpy.float32)
    with pytest.raises(ValueError, match="ep_dispatch"):
        moe_mod.moe(p, x, cfg, dispatch="bogus")


# ---------------------------------------------------------------------------
# mesh-spec builder units (in-process: parsing needs no devices)
# ---------------------------------------------------------------------------

def test_build_mesh_single_device_specs():
    for spec in (1, "1", "model=1", "data=1,model=1", "1x1"):
        mesh = shd.build_mesh(spec)
        assert mesh.axis_names == ("data", "model")
        assert mesh.shape["model"] == 1

    mesh = shd.build_mesh("pod=1,data=1,model=1")
    assert mesh.axis_names == ("pod", "data", "model")


def test_build_mesh_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown mesh axis"):
        shd.build_mesh("experts=2")
    with pytest.raises(ValueError, match="must be >= 1"):
        shd.build_mesh("model=0")
    with pytest.raises(ValueError, match="must be >= 1"):
        shd.build_mesh(data=2, model=-1)
    with pytest.raises(ValueError, match="2-D or 3-D"):
        shd.build_mesh("1x1x1x1")
    with pytest.raises(ValueError, match="spec or keyword"):
        shd.build_mesh("model=1", model=1)
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        shd.build_mesh(model=16 * n)
