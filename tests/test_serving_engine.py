"""Device-resident continuous-batching engine (runtime/serve.py).

Covers the compiled serving loop end to end: greedy bit-parity with the
seed host loop (exact-length prefill + one decode per token) across mixed
prompt lengths, chunk boundaries and staggered admissions — under BOTH KV
layouts (the paged block-table pool and the dense per-slot reservation);
fused multi-step decode (`decode_steps`) equivalence; on-device sampling
reproducibility; admission-time EOS termination; the context-manager
contract; max_seq budget clipping; and the paged pool's allocation /
reclaim / backpressure behavior under cache pressure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.runtime.sampling import SamplingConfig
from repro.runtime.serve import Engine

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b", smoke=True)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def reference_greedy(cfg, params, prompt, max_new, max_seq):
    """The seed engine's per-request math: exact-length prefill, then one
    greedy decode per token — the parity oracle for the compiled loop."""
    prompt = np.asarray(prompt, np.int32)
    caches = M.init_cache(cfg, 1, max_seq)
    logits, caches = M.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                               cfg, caches)
    toks = [int(jnp.argmax(logits[0]))]
    for i in range(max_new - 1):
        if len(prompt) + i >= max_seq - 1:
            break
        pos = jnp.asarray([len(prompt) + i], jnp.int32)
        lg, caches = M.decode_step(params, jnp.asarray([[toks[-1]]]), cfg,
                                   caches, pos)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


# --- greedy parity ----------------------------------------------------------

LAYOUTS = ("dense", "paged")


@pytest.mark.parametrize("layout", LAYOUTS)
def test_greedy_parity_chunked_prefill_staggered_admissions(granite, layout):
    """Token streams bit-identical to the seed loop: prompt lengths below /
    at / across the 16-token prefill-chunk boundary, admitted in waves
    through 2 slots (every request after the first two queues behind a
    running one) — the paged block-table layout must match the dense
    reservation bit for bit."""
    cfg, params = granite
    rng = np.random.default_rng(0)
    lens = (3, 16, 17, 29, 40)
    news = (5, 1, 7, 4, 6)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in lens]
    refs = [reference_greedy(cfg, params, p, n, 64)
            for p, n in zip(prompts, news)]
    eng = Engine(cfg, params, num_slots=2, max_seq=64, kv_layout=layout)
    reqs = [eng.submit(p, n) for p, n in zip(prompts, news)]
    eng.run()
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.out_tokens == ref
    if layout == "paged":
        # everything terminated -> no slot holds a reference; the only
        # pages still in use are the ones the (default-on) prefix cache
        # retains for future warm admissions
        assert eng.pool.slot_refs_total == 0
        assert eng.pages_in_use == eng.prefix.cached_pages
        assert 0 < eng.pages_high_water <= eng.num_pages


@pytest.mark.parametrize("layout", LAYOUTS)
def test_decode_steps_equivalent_to_single_step_greedy(granite, layout):
    """Fusing N decode steps per tick must not change greedy streams —
    only the host sync count (one per tick, not one per token)."""
    cfg, params = granite
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (6, 21, 11)]
    streams, syncs = {}, {}
    for ds in (1, 3, 8):
        eng = Engine(cfg, params, num_slots=2, max_seq=64, decode_steps=ds,
                     kv_layout=layout)
        reqs = [eng.submit(p, 7) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        streams[ds] = [r.out_tokens for r in reqs]
        syncs[ds] = (eng.n_syncs, eng.n_generated)
    assert streams[1] == streams[3] == streams[8]
    # fewer ticks -> fewer syncs for the same token count
    assert syncs[8][1] == syncs[1][1]
    assert syncs[8][0] < syncs[3][0] < syncs[1][0]


@pytest.mark.multidevice
def test_greedy_parity_under_mesh():
    """The parity suite with a DP×TP mesh active: staggered admissions
    through 2 slots, chunked prefill across the 16-token boundary, and
    decode_steps fusion changing nothing — streams are bit-identical
    across decode_steps and across runs.  (Bit-parity against a B=1
    host loop is NOT asserted here: GSPMD partitions e.g. the sequence
    axis only at chunk-divisible shapes, so reduction order — and thus
    float rounding — legitimately differs between the two programs.)"""
    from conftest import run_multidevice
    out = run_multidevice("""
from repro.configs import get_config
from repro.models import model as M
from repro.runtime.serve import Engine

cfg = get_config("granite-8b", smoke=True)
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (7, 19, 33)]

streams = []
for ds in (1, 4, 1):                  # rerun ds=1 to check determinism
    with Engine(cfg, params, num_slots=2, max_seq=64, mesh="data=2,model=4",
                decode_steps=ds) as eng:
        reqs = [eng.submit(p, 5) for p in prompts]
        eng.run()
        assert all(r.done and len(r.out_tokens) == 5 for r in reqs)
        assert all(0 <= t < cfg.vocab_size
                   for r in reqs for t in r.out_tokens)
        streams.append([r.out_tokens for r in reqs])
assert streams[0] == streams[1] == streams[2]
print("MESH_PARITY_OK")
""")
    assert "MESH_PARITY_OK" in out


# --- sampling ---------------------------------------------------------------

def test_sampling_reproducible_and_slot_independent(granite):
    """Same request seed -> same stream, even when the request lands in a
    different slot behind different traffic; different seeds -> different
    streams (vocab 256, 8 tokens: collision odds are negligible)."""
    cfg, params = granite
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=9)
    streams = []
    for n_before in (0, 1):              # second run: lands in another slot
        eng = Engine(cfg, params, num_slots=2, max_seq=64,
                     sampling="temperature", temperature=1.2)
        for _ in range(n_before):
            eng.submit(rng.integers(0, cfg.vocab_size, size=4), 3)
        r = eng.submit(prompt, 8, seed=42)
        eng.run()
        assert r.done and len(r.out_tokens) == 8
        streams.append(r.out_tokens)
    assert streams[0] == streams[1]

    eng = Engine(cfg, params, num_slots=2, max_seq=64,
                 sampling="temperature", temperature=1.2)
    a = eng.submit(prompt, 8, seed=1)
    b = eng.submit(prompt, 8, seed=2)
    eng.run()
    assert a.out_tokens != b.out_tokens


@pytest.mark.parametrize("method,kw", [
    ("temperature", {}),
    ("top_k", {"top_k": 5}),
    ("top_p", {"top_p": 0.9}),
])
def test_stochastic_methods_emit_valid_streams(granite, method, kw):
    cfg, params = granite
    eng = Engine(cfg, params, num_slots=2, max_seq=64, sampling=method,
                 temperature=0.8, decode_steps=2, **kw)
    r = eng.submit(np.arange(1, 8, dtype=np.int32), 6, seed=7)
    eng.run()
    assert r.done and len(r.out_tokens) == 6
    assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_sampling_config_validation(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="method"):
        Engine(cfg, params, num_slots=1, max_seq=8, sampling="beam")
    with pytest.raises(ValueError, match="top_k"):
        SamplingConfig(method="top_k", top_k=0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingConfig(method="top_p", top_p=0.0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingConfig(method="temperature", temperature=0.0)
    with pytest.raises(ValueError, match="decode_steps"):
        Engine(cfg, params, num_slots=1, max_seq=8, decode_steps=0)


# --- termination ------------------------------------------------------------

def test_eos_on_first_token_terminates_at_admission(granite):
    """Regression: the seed `_admit` appended the prefill token without an
    eos check, so a request whose very first token is EOS burned
    max_new_tokens decode ticks.  It must finish at admission, with zero
    decode ticks when nothing else is active."""
    cfg, params = granite
    prompt = np.arange(1, 10, dtype=np.int32)
    tok0 = reference_greedy(cfg, params, prompt, 1, 64)[0]
    eng = Engine(cfg, params, num_slots=2, max_seq=64, eos_id=tok0)
    r = eng.submit(prompt, 8)
    eng.run()
    assert r.done
    assert r.out_tokens == [tok0]
    assert eng.n_ticks == 0


def test_eos_mid_stream_stops_generation(granite):
    """EOS sampled inside a fused tick stops the slot there, bit-matching
    the seed loop's truncation."""
    cfg, params = granite
    prompt = np.arange(3, 12, dtype=np.int32)
    full = reference_greedy(cfg, params, prompt, 8, 64)
    eos = full[3]                       # terminate after the 4th token
    want = full[:4]
    for ds in (1, 4):
        eng = Engine(cfg, params, num_slots=2, max_seq=64, eos_id=eos,
                     decode_steps=ds)
        r = eng.submit(prompt, 8)
        eng.run()
        assert r.done and r.out_tokens == want


@pytest.mark.parametrize("layout", LAYOUTS)
def test_max_seq_clips_generation(granite, layout):
    """A request whose budget overruns the cache stops at max_seq-1, like
    the seed loop."""
    cfg, params = granite
    prompt = np.arange(1, 29, dtype=np.int32)          # plen 28
    ref = reference_greedy(cfg, params, prompt, 16, 32)
    eng = Engine(cfg, params, num_slots=2, max_seq=32, kv_layout=layout)
    r = eng.submit(prompt, 16)
    eng.run()
    assert r.done
    assert r.out_tokens == ref
    assert len(r.out_tokens) == 1 + (32 - 1 - 28)      # admission + 3 decodes


@pytest.mark.parametrize("layout", LAYOUTS)
def test_final_chunk_crossing_cache_end_tight_cache(granite, layout):
    """Regression: with max_seq=24 and plen=19 the padded final chunk
    (rows 16..31) crosses the cache end.  Dense slides the chunk back
    inside the cache (dynamic_update_slice would clamp the write start and
    scramble rows; the re-covered rows recompute to identical values);
    paged simply drops the out-of-range rows at scatter time — and 24 is
    not page-aligned, so this also exercises the gathered view's max_seq
    slice.  Both must bit-match the seed loop."""
    cfg, params = granite
    prompt = np.arange(1, 20, dtype=np.int32)          # plen 19
    ref = reference_greedy(cfg, params, prompt, 4, 24)
    eng = Engine(cfg, params, num_slots=1, max_seq=24, kv_layout=layout)
    r = eng.submit(prompt, 4)
    eng.run()
    assert r.done and r.out_tokens == ref


def test_recurrent_slot_reuse_starts_from_fresh_state():
    """Regression: recurrent mixers (chunk=1 prefill) accumulate state, so
    admission must reset the slot to pristine init values — a request
    served after another occupant (and idle ticks) must produce the same
    stream as one served by a fresh engine."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab_size, size=6)
    pb = rng.integers(0, cfg.vocab_size, size=8)

    fresh = Engine(cfg, params, num_slots=1, max_seq=48)
    want = fresh.submit(pb, 4)
    fresh.run()

    eng = Engine(cfg, params, num_slots=1, max_seq=48)
    eng.submit(pa, 5)
    eng.run()                           # occupy + drain the only slot
    got = eng.submit(pb, 4)
    eng.run()
    assert got.done and got.out_tokens == want.out_tokens


@pytest.mark.parametrize("layout", LAYOUTS)
def test_oversized_and_empty_prompts_rejected(granite, layout):
    """A prompt that can't fit the cache would clamp its chunk offsets
    into earlier rows and 'complete' with scrambled state — submit() must
    reject it up front (and the empty prompt, which has no last logits).
    A prompt of exactly max_seq-1 is the admissible ceiling in BOTH
    layouts: it prefills, emits its admission token, and stops with no
    decode room."""
    cfg, params = granite
    eng = Engine(cfg, params, num_slots=1, max_seq=32, kv_layout=layout,
                 prefix_cache=False)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(np.arange(32, dtype=np.int32), 4)   # needs max_seq-1
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(np.zeros((0,), np.int32), 4)
    r = eng.submit(np.arange(31, dtype=np.int32), 4)   # boundary fits
    eng.run()
    assert r.done and len(r.out_tokens) == 1           # no decode room
    if layout == "paged":
        assert eng.pages_in_use == 0                   # reclaimed at admit


def test_submit_rejects_nonpositive_max_new_tokens(granite):
    """Regression: budgets0 = max_new_tokens - 1 underflowed to -1 while
    the admit path still emitted the prefill token, so a request asking
    for 0 tokens got 1.  Now rejected at submit for both layouts."""
    cfg, params = granite
    for layout in LAYOUTS:
        eng = Engine(cfg, params, num_slots=1, max_seq=32, kv_layout=layout)
        for bad in (0, -3):
            with pytest.raises(ValueError, match="max_new_tokens"):
                eng.submit([1, 2, 3], max_new_tokens=bad)
        assert not eng._queue                          # nothing enqueued
        r = eng.submit([1, 2, 3], max_new_tokens=1)    # boundary is legal
        eng.run()
        assert r.done and len(r.out_tokens) == 1


# --- paged pool: pressure, reclaim, backpressure ----------------------------

def test_pool_exhaustion_backpressure_and_reclaim(granite):
    """Submit more live tokens than the pool holds: admission must hold
    queued requests (FIFO) until terminating requests reclaim pages, every
    request must still complete with seed-loop parity, and the high-water
    mark must respect the pool bound."""
    cfg, params = granite
    rng = np.random.default_rng(7)
    # each request: 20-token prompt + 10 new = 29 rows -> 2 pages of 16;
    # pool of 3 pages fits only ONE resident request at a time
    prompts = [rng.integers(0, cfg.vocab_size, size=20) for _ in range(4)]
    refs = [reference_greedy(cfg, params, p, 10, 64) for p in prompts]
    # prefix_cache off: this test pins the bare allocator floor (exact
    # high-water, reclaim to zero) without cache retention in the way
    eng = Engine(cfg, params, num_slots=4, max_seq=64, kv_layout="paged",
                 num_pages=3, prefix_cache=False)
    reqs = [eng.submit(p, 10) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)
    assert [r.out_tokens for r in reqs] == refs
    assert eng.pages_high_water == 2                   # one resident at a time
    assert eng.pages_in_use == 0                       # all reclaimed
    # a single request that could never fit the pool is rejected up front
    with pytest.raises(ValueError, match="pages"):
        eng.submit(rng.integers(0, cfg.vocab_size, size=60), 4)


def test_paged_pool_capacity_below_dense_reservation(granite):
    """The capacity argument of the paged layout: requests whose dense
    footprint (num_slots * max_seq rows) exceeds the pool still serve
    fine because occupancy is bounded by live tokens, and slots admit
    concurrently whenever pages allow."""
    cfg, params = granite
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, size=n)
               for n in (5, 9, 13, 6, 11, 8)]
    refs = [reference_greedy(cfg, params, p, 6, 64) for p in prompts]
    # dense would reserve 4 slots x 64 rows = 16 pages; give the pool 4
    # (prefix_cache off: occupancy bounds are the point, not retention)
    eng = Engine(cfg, params, num_slots=4, max_seq=64, kv_layout="paged",
                 num_pages=4, prefix_cache=False)
    reqs = [eng.submit(p, 6) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)
    assert [r.out_tokens for r in reqs] == refs
    assert eng.pages_high_water <= 4 < 4 * 64 // cfg.page_size


def test_recurrent_paged_parity_with_chunked_boundary():
    """Recurrent mixers (prefill_chunk forced to 1) drive the paged layout
    through the per-token admission path; streams must match the dense
    layout bit for bit, including a prompt long enough to span multiple
    pages with page_size=4."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True).replace(page_size=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (6, 9)]
    streams = {}
    for layout in LAYOUTS:
        eng = Engine(cfg, params, num_slots=2, max_seq=48, kv_layout=layout)
        assert eng.prefill_chunk == 1
        reqs = [eng.submit(p, 4) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        streams[layout] = [r.out_tokens for r in reqs]
    assert streams["dense"] == streams["paged"]


def test_kv_layout_validation(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(cfg, params, num_slots=1, max_seq=16, kv_layout="blocked")
    for bad in (-2, 0):                 # 0 must raise, not silently default
        with pytest.raises(ValueError, match="num_pages"):
            Engine(cfg, params, num_slots=1, max_seq=16, num_pages=bad)


# --- context manager --------------------------------------------------------

def test_context_manager_releases_sharding_ctx_on_raise(granite):
    """Engine(mesh=...) activates a process-global sharding ctx; the
    context manager must release it even when serving raises."""
    cfg, params = granite
    assert shd.active() is None
    with pytest.raises(RuntimeError, match="boom"):
        with Engine(cfg, params, num_slots=2, max_seq=32, mesh=1) as eng:
            assert shd.active() is not None
            r = eng.submit([1, 2, 3], 3)
            eng.run()
            assert r.done and len(r.out_tokens) == 3
            raise RuntimeError("boom")
    assert shd.active() is None
    # close() is idempotent, and a meshless engine is a no-op manager
    with Engine(cfg, params, num_slots=1, max_seq=16) as eng:
        eng.close()
    eng.close()
