"""Integration: QAT training learns; optimizer state (incl. Q8 moments)
survives checkpoint round-trips; schedules behave."""
import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core.bramac_linear import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.optim import adamw

jax.config.update("jax_platform_name", "cpu")


def test_qat_training_learns():
    """30 steps through the BRAMAC int8 STE path: loss decreases."""
    cfg = get_config("granite-8b", smoke=True).replace(
        quant=QuantConfig(enabled=True, bits_w=8, bits_a=8))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=2e-3, weight_decay=0.0)
    state = adamw.init(params, ocfg)
    pipe = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=32, global_batch=4))

    @jax.jit
    def step(params, state, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg), has_aux=True)(params)
        params, state, _ = adamw.apply(params, state, g, ocfg)
        return params, state, loss

    losses = []
    for i in range(30):
        params, state, loss = step(params, state, pipe.batch(i))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:3]


def test_optimizer_state_checkpoint_roundtrip(tmp_path):
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32))}
    cfg = adamw.AdamWConfig(quantize_state=True)
    state = adamw.init(params, cfg)
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 32))}
    params, state, _ = adamw.apply(params, state, grads, cfg)

    tree = {"params": params, "opt": state}
    ckpt.save(str(tmp_path), 1, tree)
    back = ckpt.restore(str(tmp_path), 1, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored state continues training identically
    p1, s1, _ = adamw.apply(params, state, grads, cfg)
    p2, s2, _ = adamw.apply(back["params"], back["opt"], grads, cfg)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-7)


def test_lr_schedule_shape():
    lrs = [float(adamw.lr_schedule(s, 1e-3, warmup=10, total=100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] < lrs[1]                   # decayed
    assert lrs[-1] >= 1e-4 - 1e-12            # min_frac floor
