"""Pin the 10 assigned architecture configs to the assignment sheet."""
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.archs import SHAPES, all_cells, shape_applicable

ASSIGNMENT = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "granite-8b": (36, 4096, 32, 8, 14336, 49152),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
}

MOE = {"dbrx-132b": (16, 4), "qwen3-moe-30b-a3b": (128, 8),
       "jamba-1.5-large-398b": (16, 2)}


def test_all_archs_registered():
    assert set(ARCH_IDS) == set(ASSIGNMENT)


@pytest.mark.parametrize("arch", sorted(ASSIGNMENT))
def test_exact_dims(arch):
    c = get_config(arch)
    L, d, H, kv, ff, V = ASSIGNMENT[arch]
    assert c.num_layers == L and c.d_model == d
    assert c.num_heads == H and c.num_kv_heads == kv
    assert c.vocab_size == V
    if arch in MOE:
        assert (c.num_experts, c.experts_per_token) == MOE[arch]
        assert c.expert_d_ff == ff or c.d_ff == ff
    else:
        assert c.d_ff == ff


def test_param_counts_match_advertised():
    expect = {"dbrx-132b": 132, "qwen3-moe-30b-a3b": 30,
              "jamba-1.5-large-398b": 398, "minicpm3-4b": 4.3,
              "internlm2-20b": 20, "granite-8b": 8.3,
              "musicgen-large": 3.3}
    for arch, bn in expect.items():
        got = get_config(arch).param_count() / 1e9
        assert abs(got - bn) / bn < 0.12, (arch, got)


def test_moe_active_params():
    c = get_config("jamba-1.5-large-398b")
    assert abs(c.active_param_count() / 1e9 - 94) < 6      # 94B active
    q = get_config("qwen3-moe-30b-a3b")
    assert abs(q.active_param_count() / 1e9 - 3.0) < 0.6   # A3B


def test_hybrid_pattern_ratios():
    c = get_config("jamba-1.5-large-398b")
    mixers = [s.split("+")[0] for s in c.layer_pattern]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    ffs = [s.split("+")[1] for s in c.layer_pattern]
    assert ffs.count("moe") == 4                            # every other
    x = get_config("xlstm-1.3b")
    mixers = [s.split("+")[0] for s in x.layer_pattern]
    assert mixers.count("mlstm") == 7 and mixers.count("slstm") == 1
    v = get_config("llama-3.2-vision-11b")
    assert [s.split("+")[0] for s in v.layer_pattern].count("xattn") == 1


def test_cells_and_applicability():
    cells = all_cells()
    assert len(cells) == 40                                 # 10 archs × 4
    skipped = [(a, s) for a, s in cells if not shape_applicable(a, s)]
    assert len(skipped) == 8                                # full-attn long_500k
    assert all(s == "long_500k" for _, s in skipped)
    assert shape_applicable("jamba-1.5-large-398b", "long_500k")
    assert shape_applicable("xlstm-1.3b", "long_500k")


def test_shapes_table():
    assert SHAPES["train_4k"] == {"seq": 4096, "batch": 256, "kind": "train"}
    assert SHAPES["long_500k"]["seq"] == 524288
    assert SHAPES["decode_32k"]["kind"] == "decode"


def test_smoke_configs_are_small():
    for arch in ARCH_IDS:
        c = get_config(arch, smoke=True)
        assert c.param_count() < 5e6, arch
        assert c.layer_pattern == get_config(arch).layer_pattern or \
            c.family in ("dense", "moe", "audio")
