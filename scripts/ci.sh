#!/usr/bin/env bash
# Tier-1 CI entry point, staged:
#
#   lint        python -m pyflakes src tests benchmarks scripts
#               (covers src/repro/kernels — bramac_matmul, ops and the
#               paged_attention decode kernel — alongside the rest of the
#               tree.  Reports SKIP — loudly, in the summary — when
#               pyflakes isn't installed, but still runs a syntax-only
#               compileall pass so new modules are checked offline.
#               `pip install .[dev]` provides pyflakes.)
#   tests       full pytest suite minus `multidevice`, then the marked
#               multidevice subset in ONE 8-virtual-device pass
#               (XLA_FLAGS=--xla_force_host_platform_device_count=8 makes
#               tests/conftest.py run them in-process instead of each
#               spawning its own subprocess)
#   bench-smoke benchmarks/run.py --fast, recording --json for the gate
#   bench-gate  scripts/check_bench.py against benchmarks/baseline.json
#               (exact match on deterministic paper quantities, generous
#               wall-time tolerance — see ROADMAP.md §CI)
#
#   scripts/ci.sh                 # all stages
#   scripts/ci.sh lint tests      # a subset, in the given order
#
# The suite must pass with zero collection errors in the offline container:
# `hypothesis` is OPTIONAL (tests/_hypothesis_compat.py falls back to
# deterministic example grids when it is absent).
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BENCH_JSON="${TMPDIR:-/tmp}/ci_bench_$$.json"
SMOKE_RAN=0

# stages exit 0 = PASS, 77 = SKIP (tool unavailable — visible in the
# summary, does not fail the run), anything else = FAIL
SKIP_RC=77

stage_lint() {
    if python -c "import pyflakes" 2>/dev/null; then
        python -m pyflakes src tests benchmarks scripts
    else
        # pyflakes missing (offline container): fall back to a syntax-only
        # pass so newly added modules still get checked, then report SKIP
        # so the summary shows real lint never ran
        python -m compileall -q src tests benchmarks scripts || return 1
        echo "pyflakes not installed (pip install .[dev]) — syntax-only pass"
        return $SKIP_RC
    fi
}

stage_tests() {
    python -m pytest -q -m "not multidevice" &&
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python -m pytest -q -m multidevice
}

stage_bench_smoke() {
    SMOKE_RAN=1
    python -m benchmarks.run --fast --json "$BENCH_JSON"
}

stage_bench_gate() {
    if [ -f "$BENCH_JSON" ]; then
        python scripts/check_bench.py --fresh "$BENCH_JSON"
    elif [ "$SMOKE_RAN" = 1 ]; then
        # bench-smoke ran and crashed before writing JSON: don't burn
        # minutes re-running the same failing sweep just to fail again
        echo "bench-smoke produced no JSON — gate fails without re-running"
        return 1
    else
        python scripts/check_bench.py      # bench-smoke skipped: run fresh
    fi
}

STAGES=("$@")
[ ${#STAGES[@]} -eq 0 ] && STAGES=(lint tests bench-smoke bench-gate)

# validate every stage name BEFORE running any (a typo'd later stage must
# not abort after minutes of earlier stages, skipping summary/cleanup)
for stage in "${STAGES[@]}"; do
    if ! declare -F "stage_${stage//-/_}" >/dev/null; then
        echo "ci.sh: unknown stage '$stage'" >&2
        exit 2
    fi
done

# per-stage wall time rides in the summary so CI-duration regressions
# (a bench gate that quietly doubled, a test suite that grew a minute)
# are visible at a glance
declare -a SUMMARY
FAILED=0
for stage in "${STAGES[@]}"; do
    fn="stage_${stage//-/_}"
    echo "=== ci stage: $stage ==="
    t0=$SECONDS
    "$fn"
    rc=$?
    dt=$((SECONDS - t0))
    if [ "$rc" -eq 0 ]; then
        SUMMARY+=("PASS  $stage  (${dt}s)")
    elif [ "$rc" -eq "$SKIP_RC" ]; then
        SUMMARY+=("SKIP  $stage  (${dt}s)")
    else
        SUMMARY+=("FAIL  $stage  (${dt}s)")
        FAILED=1
    fi
done
rm -f "$BENCH_JSON"

echo "=== ci summary ==="
for line in "${SUMMARY[@]}"; do
    echo "$line"
done
exit $FAILED
