#!/usr/bin/env bash
# Tier-1 CI entry point: full test suite + a fast benchmark smoke.
#
#   scripts/ci.sh            # everything
#   scripts/ci.sh tests/test_kernels.py   # forward extra args to pytest
#
# The suite must pass with zero collection errors in the offline container:
# `hypothesis` is OPTIONAL (tests/_hypothesis_compat.py falls back to
# deterministic example grids when it is absent).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q "$@"
python -m benchmarks.run --fast
