"""Refresh the generated sections of EXPERIMENTS.md in place (idempotent —
works after the initial placeholder splice by replacing section bodies).

    PYTHONPATH=src python scripts/refresh_experiments.py
"""
import io
import re
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "scripts")


def capture(argv):
    old = sys.argv
    buf = io.StringIO()
    try:
        sys.argv = argv
        with redirect_stdout(buf):
            import importlib
            if "roofline" in argv[0]:
                import make_roofline_table as m
            else:
                import perf_report as m
            importlib.reload(m)
            m.main()
    finally:
        sys.argv = old
    return buf.getvalue().strip()


def main():
    pod = capture(["scripts/make_roofline_table.py", "--mesh", "pod"])
    multi = capture(["scripts/make_roofline_table.py", "--mesh", "multipod"])
    perf = capture(["scripts/perf_report.py"])

    i = pod.find("### §Roofline")
    dry_tbl, roof_tbl = pod[:i].strip(), pod[i:].strip()

    text = open("EXPERIMENTS.md").read()

    def replace_span(text, start_pat, end_pat, new):
        s = re.search(start_pat, text).start()
        e = re.search(end_pat, text[s:]).start() + s
        return text[:s] + new + "\n\n" + text[e:]

    text = replace_span(text, r"### §Dry-run \(mesh = 16x16\)",
                        r"## §Roofline", dry_tbl + "\n\n" + multi)
    text = replace_span(text, r"### §Roofline \(single-pod",
                        r"## §Perf", roof_tbl)
    # the fenced perf table
    text = re.sub(r"```\n=== .*?```", "```\n" + perf + "\n```", text,
                  flags=re.S)
    open("EXPERIMENTS.md", "w").write(text)
    print("refreshed")


if __name__ == "__main__":
    main()
