"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python scripts/make_roofline_table.py [--dir results/dryrun]
"""
import argparse
import glob
import json
import os


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x:.0f}B"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, f"*__{args.mesh}.json"))):
        rows.append(json.load(open(f)))

    print("### §Dry-run (mesh =", "2x16x16)" if args.mesh == "multipod"
          else "16x16)")
    print()
    print("| arch | shape | status | compile | bytes/dev (args+temp) | "
          "HLO GFLOP/dev | coll GB/dev |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['status']}: {reason} "
                  f"| | | | |")
            continue
        m = r["memory"]
        print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']}s | "
              f"{fmt_b(m['args_bytes_per_dev'])}+"
              f"{fmt_b(m['temp_bytes_per_dev'])} | "
              f"{r['hlo_flops_per_dev'] / 1e9:.0f} | "
              f"{r['collective_bytes_total_per_dev'] / 1e9:.2f} |")

    if args.mesh != "pod":
        return
    print()
    print("### §Roofline (single-pod 16x16, v5e: 197TF bf16 / 819GB/s HBM / "
          "50GB/s ICI-link)")
    print()
    print("`mem-floor` is the aliasing-aware analytic lower bound on the "
          "memory term (launch/analysis.py): XLA's `bytes accessed` counts "
          "whole operands for in-place cache updates, so decode memory "
          "terms are upper bounds.")
    print()
    print("`frac` brackets the compute fraction of roofline: "
          "[compute/max(compute, memory, coll), compute/max(compute, "
          "mem-floor, coll)] — the true value lies between because the "
          "measured memory term is an upper bound.")
    print()
    print("| arch | shape | compute | memory | mem-floor | collective | "
          "dominant | frac [lo, hi] | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|---|")
    import sys
    sys.path.insert(0, "src")
    from repro.launch.analysis import min_memory_term
    flagged = False
    for r in rows:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        floor = min_memory_term(r["arch"], r["shape"])
        mark = ""
        if not r.get("cost_probe_unrolled", True):
            mark, flagged = " †", True
        c, m, co = ro["compute_s"], ro["memory_s"], ro["collective_s"]
        frac_lo = c / max(c, m, co)
        frac_hi = c / max(c, floor, co)
        print(f"| {r['arch']} | {r['shape']}{mark} | "
              f"{fmt_s(c)} | {fmt_s(m)} | {fmt_s(floor)} | {fmt_s(co)} | "
              f"**{ro['dominant']}** | [{frac_lo:.2f}, {frac_hi:.2f}] | "
              f"{ro['useful_ratio']:.2f} |")
    if flagged:
        print()
        print("† scan-module accounting (the unrolled cost probe exceeded "
              "its compile-time budget): FLOP/byte/collective counters "
              "count loop bodies once — MODEL/HLO > 1 is the undercount "
              "signature.  Compile proof and memory_analysis are "
              "unaffected; see the moe_sort variant of the same cell in "
              "§Perf for exact-probe numbers.")


if __name__ == "__main__":
    main()
