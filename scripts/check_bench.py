#!/usr/bin/env python
"""Benchmark-regression gate: compare a fresh `benchmarks/run.py --fast
--json` run against the checked-in benchmarks/baseline.json.

Policy (documented in ROADMAP.md §CI):
  * `deterministic` records reproduce paper quantities (Table II, Figs
    7/9/10/11/13) or integer engine bookkeeping (the MoE drop counts, the
    serving engine's generated-token/tick schedule) — their `derived`
    strings must match the baseline EXACTLY; any drift is a correctness
    regression, not noise.
  * every baseline record must still be produced (a missing row means a
    bench crashed or a distributed subprocess failed);
  * wall times are gated with a deliberately generous tolerance
    (default 20x, with a 200us floor) — CI containers are noisy, so only
    order-of-magnitude blowups fail.

Usage:
    python scripts/check_bench.py                 # runs --fast itself
    python scripts/check_bench.py --fresh out.json   # reuse a prior run
    python scripts/check_bench.py --update        # rewrite the baseline

Exit status 0 = gate passed, 1 = regression, 2 = couldn't run.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO_ROOT, "benchmarks", "baseline.json")

US_FLOOR = 200.0          # timings under this are jitter, never gated


def run_fast_bench(json_path: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast", "--json",
         json_path], cwd=REPO_ROOT, env=env)
    if proc.returncode != 0:
        print(f"check_bench: benchmark run failed (rc={proc.returncode})")
        raise SystemExit(2)


def load_run(path: str) -> tuple[dict, dict[str, dict]]:
    with open(path) as fh:
        data = json.load(fh)
    return data, {r["name"]: r for r in data["records"]}


def compare(base: dict[str, dict], fresh: dict[str, dict],
            tolerance: float) -> list[str]:
    failures = []
    for name, b in base.items():
        f = fresh.get(name)
        if f is None:
            failures.append(f"MISSING   {name}: present in baseline, "
                            f"absent from fresh run")
            continue
        if b.get("deterministic"):
            if f["derived"] != b["derived"]:
                failures.append(f"DERIVED   {name}: {f['derived']!r} != "
                                f"baseline {b['derived']!r}")
            continue
        allowed = tolerance * max(float(b["us_per_call"]), US_FLOOR)
        if float(f["us_per_call"]) > allowed:
            failures.append(
                f"WALLTIME  {name}: {f['us_per_call']:.1f}us > "
                f"{allowed:.0f}us ({tolerance:g}x baseline "
                f"{b['us_per_call']:.1f}us)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--fresh", default=None, metavar="PATH",
                    help="reuse an existing --json output instead of "
                         "running the --fast bench")
    ap.add_argument("--tolerance", type=float, default=20.0,
                    help="wall-time blowup factor that fails the gate")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh run")
    args = ap.parse_args()

    tmpdir = None
    fresh_path = args.fresh
    if fresh_path is None:
        tmpdir = tempfile.mkdtemp(prefix="check_bench_")
        fresh_path = os.path.join(tmpdir, "bench.json")
        run_fast_bench(fresh_path)
    try:
        meta, fresh = load_run(fresh_path)
        if args.update:
            # a partial run must never gut the gate: the baseline has to
            # come from a full `--fast` sweep covering every prior record
            if meta.get("only") or not meta.get("fast"):
                print("check_bench: refusing --update from a partial run "
                      f"(fast={meta.get('fast')}, only={meta.get('only')}) "
                      "— regenerate with `benchmarks/run.py --fast --json`")
                return 2
            if os.path.exists(args.baseline):
                _, base = load_run(args.baseline)
                missing = sorted(set(base) - set(fresh))
                if missing:
                    print(f"check_bench: refusing --update — fresh run "
                          f"lost {len(missing)} baseline record(s): "
                          f"{', '.join(missing[:5])}")
                    return 2
            shutil.copyfile(fresh_path, args.baseline)
            print(f"check_bench: baseline updated "
                  f"({len(fresh)} records -> {args.baseline})")
            return 0
        if not os.path.exists(args.baseline):
            print(f"check_bench: no baseline at {args.baseline} — run with "
                  f"--update to create one")
            return 2
        base_meta, base = load_run(args.baseline)
        if (bool(meta.get("fast")) != bool(base_meta.get("fast"))
                or (meta.get("only") or None)
                != (base_meta.get("only") or None)):
            # shape-suffixed row names differ between configs — diagnose
            # the mismatch instead of reporting phantom MISSING rows
            print(f"check_bench: fresh run config "
                  f"(fast={meta.get('fast')}, only={meta.get('only')}) "
                  f"does not match baseline "
                  f"(fast={base_meta.get('fast')}, "
                  f"only={base_meta.get('only')}) — rerun with matching "
                  f"flags")
            return 2
    finally:
        if tmpdir is not None:
            shutil.rmtree(tmpdir, ignore_errors=True)

    failures = compare(base, fresh, args.tolerance)
    n_det = sum(1 for r in base.values() if r.get("deterministic"))
    print(f"check_bench: {len(base)} baseline records "
          f"({n_det} deterministic), {len(fresh)} fresh")
    for extra in sorted(set(fresh) - set(base)):
        print(f"  new (ungated): {extra}")
    if failures:
        print(f"check_bench: FAIL — {len(failures)} regression(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
