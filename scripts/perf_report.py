"""§Perf iteration report: baseline vs variants for the hillclimbed cells.

    PYTHONPATH=src python scripts/perf_report.py
"""
import glob
import json
import os
from collections import defaultdict


def load_all(d="results/dryrun"):
    cells = defaultdict(dict)
    for f in glob.glob(os.path.join(d, "*__pod*.json")):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        variant = r.get("variant", "baseline")
        cells[(r["arch"], r["shape"])][variant] = r
    return cells


def fmt(x):
    return f"{x * 1e3:9.1f}ms" if x < 10 else f"{x:9.2f}s "


def main():
    cells = load_all()
    for (arch, shape), variants in sorted(cells.items()):
        if len(variants) < 2:
            continue
        base = variants["baseline"]
        print(f"\n=== {arch} × {shape} (pod) ===")
        hdr = (f"{'variant':22s} {'compute':>11s} {'memory':>11s} "
               f"{'collective':>11s} {'dominant':>10s} {'useful':>7s} "
               f"{'peak-mem':>9s}")
        print(hdr)
        order = ["baseline"] + sorted(v for v in variants if v != "baseline")
        b = base["roofline"]
        for v in order:
            r = variants[v]
            ro = r["roofline"]
            peak = r["memory"]["peak_est_bytes_per_dev"] / 1e9
            mark = ""
            if v != "baseline":
                dom_b = max(b["compute_s"], b["memory_s"], b["collective_s"])
                dom_v = max(ro["compute_s"], ro["memory_s"],
                            ro["collective_s"])
                mark = f"  ({dom_b / dom_v:５.2f}x step-bound)" \
                    if dom_v > 0 else ""
                mark = mark.replace("５", "")
            print(f"{v:22s} {fmt(ro['compute_s'])} {fmt(ro['memory_s'])} "
                  f"{fmt(ro['collective_s'])} {ro['dominant']:>10s} "
                  f"{ro['useful_ratio']:7.3f} {peak:8.1f}G{mark}")


if __name__ == "__main__":
    main()
