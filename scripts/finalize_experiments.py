"""Splice generated tables into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python scripts/finalize_experiments.py
"""
import io
import sys
from contextlib import redirect_stdout


def capture(mod_argv):
    old = sys.argv
    buf = io.StringIO()
    try:
        sys.argv = mod_argv
        with redirect_stdout(buf):
            if "make_roofline_table" in mod_argv[0]:
                import importlib
                import make_roofline_table as m
                importlib.reload(m)
                m.main()
            else:
                import importlib
                import perf_report as m
                importlib.reload(m)
                m.main()
    finally:
        sys.argv = old
    return buf.getvalue()


def main():
    sys.path.insert(0, "scripts")
    dry_pod = capture(["scripts/make_roofline_table.py", "--mesh", "pod"])
    dry_multi = capture(["scripts/make_roofline_table.py", "--mesh",
                         "multipod"])
    perf = capture(["scripts/perf_report.py"])

    # split the pod output into dryrun and roofline sections
    idx = dry_pod.find("### §Roofline")
    dry_tbl, roof_tbl = dry_pod[:idx], dry_pod[idx:]

    text = open("EXPERIMENTS.md").read()
    text = text.replace("<!-- DRYRUN_TABLE -->",
                        dry_tbl.strip() + "\n\n" + dry_multi.strip())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof_tbl.strip())
    text = text.replace("<!-- PERF_TABLE -->",
                        "```\n" + perf.strip() + "\n```")
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md updated "
          f"({len(dry_tbl)}+{len(roof_tbl)}+{len(perf)} chars spliced)")


if __name__ == "__main__":
    main()
