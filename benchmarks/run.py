"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * us_per_call — wall time of evaluating our model/kernel for that entry,
  * derived     — the reproduced quantity compared against the paper.

Run:  PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]

--json additionally writes the rows as machine-readable records
({name, us_per_call, derived, deterministic}) for scripts/check_bench.py's
regression gate: `deterministic` rows reproduce paper quantities that must
match the checked-in benchmarks/baseline.json exactly; the rest are wall-
time measurements gated only by a generous tolerance.
"""
from __future__ import annotations

import argparse
import json
import time

RECORDS: list[dict] = []


def _timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)                      # warmup / jit
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6, out


def _row(name, us, derived, deterministic=False, record=True):
    """record=False keeps a row out of the --json gate set — for rows whose
    name/content depends on gitignored local state (results/)."""
    print(f"{name},{us:.1f},{derived}")
    if record:
        RECORDS.append({"name": name, "us_per_call": round(us, 1),
                        "derived": str(derived),
                        "deterministic": deterministic})


# --- Table II: eFSM latencies & parallelism --------------------------------

def bench_table2():
    from repro.core.efsm import BRAMAC_1DA, BRAMAC_2SA

    def table():
        return {v.name: ([v.mac2_latency(b) for b in (2, 4, 8)],
                         [v.macs_in_parallel(b) for b in (2, 4, 8)])
                for v in (BRAMAC_2SA, BRAMAC_1DA)}

    us, t = _timed(table)
    _row("table2_latency_2sa", us, "/".join(map(str, t["BRAMAC-2SA"][0]))
         + " (paper 5/7/11)", deterministic=True)
    _row("table2_latency_1da", us, "/".join(map(str, t["BRAMAC-1DA"][0]))
         + " (paper 3/4/6)", deterministic=True)
    _row("table2_parallel_2sa", us, "/".join(map(str, t["BRAMAC-2SA"][1]))
         + " (paper 80/40/20)", deterministic=True)


# --- Fig 7: adder study -----------------------------------------------------

def bench_fig7():
    from repro.core.arch_models import adder_delay_ps

    us, d = _timed(lambda: {k: adder_delay_ps(k, 32)
                            for k in ("RCA", "CBA", "CLA")})
    _row("fig7_rca_over_cba", us,
         f"{d['RCA'] / d['CBA']:.2f}x (paper 2.8x)", deterministic=True)
    _row("fig7_rca_over_cla", us,
         f"{d['RCA'] / d['CLA']:.2f}x (paper 2.5x)", deterministic=True)


# --- Fig 9: peak MAC throughput --------------------------------------------

def bench_fig9():
    from repro.core.arch_models import throughput_boost
    from repro.core.efsm import BRAMAC_1DA, BRAMAC_2SA

    paper = {("2SA", 2): 2.6, ("2SA", 4): 2.3, ("2SA", 8): 1.9,
             ("1DA", 2): 2.1, ("1DA", 4): 2.0, ("1DA", 8): 1.7}
    for variant, tag in ((BRAMAC_2SA, "2SA"), (BRAMAC_1DA, "1DA")):
        for bits in (2, 4, 8):
            us, boost = _timed(throughput_boost, bits, variant)
            _row(f"fig9_boost_{tag}_{bits}bit", us,
                 f"{boost:.2f}x (paper {paper[(tag, bits)]}x)",
                 deterministic=True)


# --- Fig 10: utilization efficiency -----------------------------------------

def bench_fig10():
    from repro.core.arch_models import utilization_advantage

    us, adv = _timed(utilization_advantage)
    _row("fig10_vs_ccb", us, f"{adv['vs_ccb']:.2f}x (paper 1.3x)",
         deterministic=True)
    _row("fig10_vs_comefa", us, f"{adv['vs_comefa']:.2f}x (paper 1.1x)",
         deterministic=True)


# --- Fig 11: GEMV speedups ---------------------------------------------------

def bench_fig11():
    from repro.core.gemv_model import max_speedups

    paper = {("persistent", 2): 3.3, ("persistent", 4): 2.8,
             ("persistent", 8): 2.4, ("nonpersistent", 2): 4.1,
             ("nonpersistent", 4): 3.4, ("nonpersistent", 8): 2.8}
    us, ms = _timed(max_speedups)
    for key, val in ms.items():
        _row(f"fig11_{key[0]}_{key[1]}bit", us / len(ms),
             f"{val:.2f}x (paper {paper[key]}x)", deterministic=True)


# --- Fig 13 / Table III: DLA case study --------------------------------------

def bench_fig13(fast=False):
    from repro.core.dla_model import average_speedups, case_study

    paper = {("alexnet", "BRAMAC-2SA"): 2.05, ("alexnet", "BRAMAC-1DA"): 1.7,
             ("resnet34", "BRAMAC-2SA"): 1.33,
             ("resnet34", "BRAMAC-1DA"): 1.52}
    t0 = time.perf_counter()
    avg = average_speedups(case_study())
    us = (time.perf_counter() - t0) * 1e6
    for (model, vname), row in avg.items():
        _row(f"fig13_{model}_{vname}", us / len(avg),
             f"{row['speedup']:.2f}x speedup / {row['rel_area']:.2f}x area "
             f"(paper {paper[(model, vname)]}x)", deterministic=True)


# --- Kernels: BRAMAC matmul & MAC2 (interpret mode on CPU) -------------------

def bench_kernels(fast=False):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.mac2 import mac2_mvm
    from repro.core.quant import qrange
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    M = K = N = 64 if fast else 128
    for bits in (2, 4, 8):
        lo, hi = qrange(bits)
        xq = jnp.asarray(rng.integers(lo, hi + 1, (M, K), dtype=np.int8))
        wq = jnp.asarray(rng.integers(lo, hi + 1, (K, N), dtype=np.int8))
        one = jnp.ones((1, 1), jnp.float32)

        def run():
            return ops.quant_matmul(xq, wq, one, one, bits_a=bits,
                                    bits_w=bits).block_until_ready()

        us, _ = _timed(run)
        macs = M * K * N
        _row(f"kernel_bramac_matmul_{bits}bit_{M}cube", us,
             f"{macs / us:.0f} MMAC/s (interpret mode, "
             f"{(bits + 1) // 2} digit passes)")

    w = jnp.asarray(rng.integers(-8, 8, (64, 32), dtype=np.int8))
    x = jnp.asarray(rng.integers(-8, 8, (32,), dtype=np.int8))
    us, _ = _timed(lambda: mac2_mvm(w, x, bits=4).block_until_ready())
    _row("kernel_mac2_mvm_alg1_4bit", us, "Algorithm 1 bit-exact MVM")


# --- Distributed: replicated vs sharded (8 virtual host devices) ------------

def _subprocess_bench(payload: str, prefix: str, fail_name: str):
    """Run a distributed bench payload in an 8-virtual-device subprocess
    (the XLA device-count flag must be set before jax import and must not
    leak into this process).  The payload sees jax/np/jnp and a
    `timed(fn) -> us` helper, and prints `<prefix>,<tag>,<us>,<us_rep>`
    rows; returns them as (tag, us, us_rep) tuples.  On a nonzero exit a
    `fail_name` failure row is emitted instead (the gate then reports the
    success rows as MISSING — a broken distributed path fails CI)."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    pre = (
        'import os\n'
        'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"\n'
        'import sys, time\n'
        f'sys.path.insert(0, {src!r})\n'
        'import jax, numpy as np, jax.numpy as jnp\n'
        'def timed(fn):\n'
        '    fn().block_until_ready()\n'
        '    t0 = time.perf_counter()\n'
        '    for _ in range(3):\n'
        '        fn().block_until_ready()\n'
        '    return (time.perf_counter() - t0) / 3 * 1e6\n'
    )
    try:
        out = subprocess.run([sys.executable, "-c", pre + payload],
                             capture_output=True, text=True, timeout=600)
    except subprocess.TimeoutExpired:
        # a hung collective must degrade to a failure row (the gate then
        # reports the success rows MISSING), not crash the whole sweep
        _row(fail_name, 0.0, "subprocess timed out after 600s")
        return []
    if out.returncode != 0:
        err = (out.stderr.strip().splitlines() or ["unknown"])[-1]
        _row(fail_name, 0.0, f"subprocess failed: {err[:100]}")
        return []
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith(prefix + ","):
            _, tag, us, us_rep = line.split(",")
            rows.append((tag, float(us), float(us_rep)))
    return rows


def bench_tp(fast=False):
    """Replicated vs TP quant_matmul."""
    dim = 128 if fast else 256
    payload = (
        'from repro.core.quant import qrange\n'
        'from repro.kernels import ops\n'
        'from repro.parallel import tp\n'
        'mesh = jax.make_mesh((8,), ("model",))\n'
        'rng = np.random.default_rng(0)\n'
        f'M = K = N = {dim}\n'
        'lo, hi = qrange(8)\n'
        'xq = jnp.asarray(rng.integers(lo, hi + 1, (M, K), dtype=np.int8))\n'
        'wq = jnp.asarray(rng.integers(lo, hi + 1, (K, N), dtype=np.int8))\n'
        'one = jnp.ones((1, 1), jnp.float32)\n'
        'rep = timed(lambda: ops.quant_matmul(xq, wq, one, one,\n'
        '                                     bits_a=8, bits_w=8))\n'
        'for part in ("k", "n"):\n'
        '    us = timed(lambda: tp.tp_quant_matmul(\n'
        '        xq, wq, one, one, mesh=mesh, bits_a=8, bits_w=8,\n'
        '        partition=part))\n'
        '    print("TPROW,%s,%.1f,%.1f" % (part, us, rep))\n'
    )
    for part, us_tp, us_rep in _subprocess_bench(payload, "TPROW",
                                                 "tp_quant_matmul_8way"):
        _row(f"tp_quant_matmul_{part}sharded_8way_{dim}cube", us_tp,
             f"replicated {us_rep:.0f}us vs tp {us_tp:.0f}us "
             f"({us_rep / us_tp:.2f}x, int8, host-CPU interpret)")


def bench_ep(fast=False):
    """Replicated vs expert-parallel vs DP×TP `ep_quant_einsum_edf`."""
    C, d = (64, 128) if fast else (128, 256)
    payload = (
        'from repro.core import bramac_linear as bl\n'
        'from repro.parallel import ep, sharding as shd\n'
        'rng = np.random.default_rng(0)\n'
        f'E, C, d, f = 8, {C}, {d}, {d}\n'
        'x = jnp.asarray(rng.normal(size=(E, C, d)).astype(np.float32))\n'
        'w = jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32))\n'
        'qw = bl.prepare_serving(w, bl.QuantConfig(enabled=True, bits_w=8))\n'
        'rep = timed(lambda: bl.serve_einsum_edf(x, qw, False))\n'
        'cases = (("ep8", shd.build_mesh("model=8"), "e", None),\n'
        '         ("dp2xtp4", shd.build_mesh("data=2,model=4"), "d",\n'
        '          "data"))\n'
        'for tag, mesh, part, dp in cases:\n'
        '    us = timed(lambda: ep.ep_quant_einsum_edf(\n'
        '        x, qw, mesh=mesh, partition=part, dp_axis=dp))\n'
        '    print("EPROW,%s,%.1f,%.1f" % (tag, us, rep))\n'
    )
    for tag, us_ep, us_rep in _subprocess_bench(payload, "EPROW",
                                                "ep_quant_einsum_8way"):
        _row(f"ep_quant_einsum_{tag}_E8x{C}x{d}", us_ep,
             f"replicated {us_rep:.0f}us vs sharded {us_ep:.0f}us "
             f"({us_rep / us_ep:.2f}x, int8, host-CPU interpret)")


def bench_ep_dispatch(fast=False):
    """Global vs per-source-capacity (GShard) `ep_moe` token dispatch:
    wall time on 8 virtual devices, plus deterministic dropped-token
    accounting from the single-device `ep.per_source_reference` simulator
    — the lossy path's drop counts are part of its contract, so they gate
    as a `deterministic` record."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import moe as moe_mod
    from repro.parallel import ep

    B, S = (2, 8) if fast else (4, 16)
    T = B * S
    payload = (
        'from repro.configs import get_config\n'
        'from repro.core import bramac_linear as bl\n'
        'from repro.models import moe as moe_mod\n'
        'from repro.parallel import ep, sharding as shd\n'
        'mesh = shd.build_mesh("model=8")\n'
        'cfg = get_config("qwen3-moe-30b-a3b", smoke=True)\n'
        'key = jax.random.PRNGKey(0)\n'
        'p = moe_mod.init_moe(key, cfg)\n'
        'qp = bl.tree_prepare_serving(\n'
        '    p, bl.QuantConfig(enabled=True, bits_w=8, bits_a=8))\n'
        'x = jax.random.normal(jax.random.fold_in(key, 1),\n'
        f'                      ({B}, {S}, cfg.d_model), jnp.float32)\n'
        'fns = {tag: jax.jit(lambda q, xx, t=tag: ep.ep_moe(\n'
        '    q, xx, cfg, mesh=mesh, capacity_factor=1.0, dispatch=t)[0])\n'
        '       for tag in ("global", "per_source")}\n'
        'rep = timed(lambda: fns["global"](qp, x))\n'
        'us_ps = timed(lambda: fns["per_source"](qp, x))\n'
        'print("EPDROW,global,%.1f,%.1f" % (rep, rep))\n'
        'print("EPDROW,per_source,%.1f,%.1f" % (us_ps, rep))\n'
    )
    for tag, us, us_rep in _subprocess_bench(payload, "EPDROW",
                                             f"ep_dispatch_8way_T{T}"):
        _row(f"ep_dispatch_{tag}_8way_T{T}", us,
             f"global {us_rep:.0f}us vs {tag} {us:.0f}us "
             f"({us_rep / us:.2f}x, int8, host-CPU interpret)")

    # deterministic drop accounting: per-source C_src = ceil(C/8) vs the
    # global rule (== per-source at ep_size=1).  The gate exact-matches
    # this row, so the routing must be platform/jax-version proof: one-hot
    # tokens select integer-valued router rows ((t·13 + e·7) mod 31 is
    # distinct within each row — no top_k tie), making keep counts pure
    # integer bookkeeping like the closed-form paper rows.
    import numpy as np

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    p = dict(moe_mod.init_moe(jax.random.PRNGKey(0), cfg))
    E, k, d = cfg.num_experts, cfg.experts_per_token, cfg.d_model
    feat = (np.arange(T) * 5) % d
    router = ((feat[:, None] * 13 + np.arange(E)[None, :] * 7) % 31)
    full = np.zeros((d, E), np.float32)
    full[feat] = router                     # rows actually hit by a token
    p["router"] = jnp.asarray(full)
    x = jnp.asarray(np.eye(d, dtype=np.float32)[feat]).reshape(B, S, d)
    Tk = T * k
    t0 = time.perf_counter()
    kept = {n: int(jnp.sum(ep.per_source_reference(
        p, x, cfg, ep_size=n, capacity_factor=1.0)[2])) for n in (1, 8)}
    us = (time.perf_counter() - t0) * 1e6
    _row(f"ep_dispatch_drops_cf1.0_T{T}", us / 2,
         f"kept global {kept[1]}/{Tk} vs per-source(8) {kept[8]}/{Tk} "
         f"(cf=1.0; the two rules drop different tokens)",
         deterministic=True)


# --- Serving engine: fused multi-step decode / continuous batching ----------

def bench_serve(fast=False):
    """Device-resident continuous-batching engine: tokens/s and mean TTFT
    at several (slots, decode_steps) points.  Host↔device syncs per
    generated token scale as 1/decode_steps (one jit'd tick emits
    decode_steps tokens per slot), so tokens/s should improve monotonically
    decode_steps=1 → 8 even on host CPU, where per-call dispatch dominates.
    Token counts and tick counts are pure scheduling arithmetic (greedy,
    no EOS: every request emits exactly max_new_tokens), so they gate as a
    `deterministic` record."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime.serve import Engine

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # max_new = budget+1 with the budget divisible by every decode_steps
    # case, so no tick carries termination-masked (wasted) scan steps
    R, T = (4, 17) if fast else (8, 17)
    cases = ((2, 1), (2, 2), (2, 8)) if fast \
        else ((2, 1), (4, 1), (4, 2), (4, 4), (4, 8))
    sched = []
    for slots, dsteps in cases:
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 20)))
                   for _ in range(R)]
        with Engine(cfg, params, num_slots=slots, max_seq=64,
                    decode_steps=dsteps) as eng:
            # warmup: compile admit + tick outside the timed window, then
            # zero the sync/tick counters so the schedule record is clean
            eng.submit(prompts[0][:4], dsteps + 1)
            eng.run()
            # best-of-5: the smoke model is dispatch-dominated, which is
            # the quantity under test, but single short passes are noisy
            dt, ttft = float("inf"), 0.0
            for _ in range(5):
                eng.n_ticks = eng.n_admit_calls = 0
                eng.n_syncs = eng.n_generated = 0
                reqs = [eng.submit(p, T) for p in prompts]
                t0 = time.perf_counter()
                eng.run()
                d = time.perf_counter() - t0
                if d < dt:
                    dt = d
                    ttft = 1e3 * float(np.mean([r.t_first - t0
                                                for r in reqs]))
            toks = sum(len(r.out_tokens) for r in reqs)
            _row(f"serve_s{slots}_n{dsteps}_r{R}x{T}", dt * 1e6 / toks,
                 f"{toks / dt:.0f} tok/s ttft {ttft:.0f}ms "
                 f"({eng.n_syncs / toks:.2f} syncs/tok)")
            sched.append(f"s{slots}n{dsteps}:{toks}tok/"
                         f"{eng.n_ticks}ticks/{eng.n_admit_calls}adm")
    _row(f"serve_schedule_r{R}x{T}", 0.0, " ".join(sched),
         deterministic=True)


# --- Paged KV cache: tok/s parity + pool occupancy vs dense -----------------

def bench_paged(fast=False):
    """Paged (block-table) KV cache vs the dense per-slot reservation at
    equal traffic: wall-time tok/s for both layouts plus the pallas
    paged-decode kernel ("kernel": paged layout, block-table walks instead
    of max_seq gathers), a KV-read GB/s wall row for the kernel engine
    (the maxtext decode-microbenchmark currency), and two deterministic
    records asserting (a) greedy streams are bit-identical across all
    three paths, (b) the paged pool's pages-in-use high-water sits
    strictly below the dense `num_slots * max_seq` reservation, and
    (c) the kernel's per-decode-step KV bytes scale with live tokens —
    strictly below the gather oracle's max_seq-proportional traffic."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime.serve import Engine

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    R, T = (4, 13) if fast else (8, 13)
    slots, max_seq, dsteps = 4, 64, 4
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 20)))
               for _ in range(R)]
    stats = {}
    for name in ("dense", "paged", "kernel"):
        kw = {"kv_layout": "paged", "decode_kernel": True} \
            if name == "kernel" else {"kv_layout": name}
        with Engine(cfg, params, num_slots=slots, max_seq=max_seq,
                    decode_steps=dsteps, **kw) as eng:
            eng.submit(prompts[0][:4], dsteps + 1)     # compile warmup
            eng.run()
            dt = float("inf")
            for _ in range(3):
                eng.pages_high_water = eng.pages_in_use
                b0, s0 = eng.kv_bytes_read, eng.kv_read_steps
                reqs = [eng.submit(p, T) for p in prompts]
                t0 = time.perf_counter()
                eng.run()
                dt = min(dt, time.perf_counter() - t0)
                # identical schedule every round -> identical deltas
                kv_bytes = eng.kv_bytes_read - b0
                kv_steps = eng.kv_read_steps - s0
            toks = sum(len(r.out_tokens) for r in reqs)
            stats[name] = {"dt": dt, "toks": toks,
                           "streams": [r.out_tokens for r in reqs],
                           "hw": eng.pages_high_water,
                           "pages": eng.num_pages,
                           "page_size": eng.page_size,
                           "kv_bytes": kv_bytes,
                           "kv_steps": kv_steps}
            _row(f"serve_{name}_s{slots}_n{dsteps}_r{R}x{T}",
                 dt * 1e6 / toks, f"{toks / dt:.0f} tok/s")
    d, p, k = stats["dense"], stats["paged"], stats["kernel"]
    dense_rows = slots * max_seq
    hw_rows = p["hw"] * p["page_size"]
    _row(f"paged_highwater_s{slots}_r{R}x{T}", 0.0,
         f"streams_equal={d['streams'] == p['streams']} "
         f"highwater {p['hw']}/{p['pages']} pages = {hw_rows} rows "
         f"vs dense {dense_rows} rows "
         f"(below={hw_rows < dense_rows})", deterministic=True)
    # per-decode-step KV bytes: engine accounting (tick-start lengths,
    # deterministic given the fixed schedule); GB/s is wall-dependent and
    # lands as a tolerance-gated wall row
    kb = k["kv_bytes"] / k["kv_steps"]
    ob = p["kv_bytes"] / p["kv_steps"]
    _row(f"paged_kernel_gbps_s{slots}_r{R}x{T}",
         k["dt"] * 1e6 / k["toks"],
         f"{k['kv_bytes'] / k['dt'] / 1e9:.3f} GB/s KV read")
    _row(f"paged_kv_bytes_s{slots}_r{R}x{T}", 0.0,
         f"streams_equal={k['streams'] == p['streams']} "
         f"kernel {kb:.0f} B/step vs gather {ob:.0f} B/step "
         f"(below={kb < ob})", deterministic=True)


# --- Prefix cache: warm-vs-cold TTFT + page sharing -------------------------

def bench_prefix(fast=False):
    """Copy-on-write prefix caching on a fixed schedule: one producer
    request registers a 32-token system prompt (2 pages at page_size=16),
    then three sharers admit warm while it is still decoding.  The
    deterministic record gates (a) bit-identical streams warm vs cold,
    (b) every warm admission skipping floor(32/16)=2 pages of prefill
    compute (2 chunks at prefill_chunk=16), (c) pages-shared high-water,
    and (d) the 4-co-resident pages-in-use high-water sitting strictly
    below 4x the cold per-request page count.  Warm-vs-cold TTFT lands as
    wall rows (`_us` suffix, tolerance-gated)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime.serve import Engine

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    slots, max_seq, T = 4, 64, 8
    rng = np.random.default_rng(0)
    sysp = rng.integers(0, cfg.vocab_size, size=32)
    prompts = [np.concatenate([sysp,
                               rng.integers(0, cfg.vocab_size, size=6)])
               for _ in range(slots)]
    per_req = -(-(len(prompts[0]) + T - 1) // cfg.page_size)

    def run_sched(on):
        # producer first (its chains register at the end of its admission
        # round), then three warm sharers co-resident with it
        with Engine(cfg, params, num_slots=slots, max_seq=max_seq,
                    prefix_cache=on) as eng:
            first = eng.submit(prompts[0], T)
            eng.step()
            rest = [eng.submit(p, T) for p in prompts[1:]]
            eng.run()
            assert first.done and all(r.done for r in rest)
            return eng, [first.out_tokens] + [r.out_tokens for r in rest]

    eng_w, s_w = run_sched(True)
    eng_c, s_c = run_sched(False)
    st = eng_w.prefix_stats()
    pages_per_warm = st["tokens_skipped"] // cfg.page_size \
        // max(st["hits"], 1)
    _row(f"prefix_sharing_s{slots}_t{T}", 0.0,
         f"streams_equal={s_w == s_c} hits={st['hits']} "
         f"pages_skipped_per_warm={pages_per_warm} "
         f"chunks_skipped={st['chunks_skipped']} "
         f"shared_hw={eng_w.pages_shared_high_water} "
         f"inuse_hw={eng_w.pages_high_water} cold={eng_c.pages_high_water} "
         f"(below_4x={eng_w.pages_high_water < 4 * per_req})",
         deterministic=True)
    # warm vs cold TTFT for a single late request behind a drained
    # engine: the warm path skips the shared pages' prefill entirely
    for label, on in (("warm", True), ("cold", False)):
        with Engine(cfg, params, num_slots=slots, max_seq=max_seq,
                    prefix_cache=on) as eng:
            pre = eng.submit(prompts[0], T)     # compile + register
            eng.run()
            assert pre.done
            best = float("inf")
            for _ in range(3 if fast else 5):
                r = eng.submit(prompts[1], T)
                t0 = time.perf_counter()
                eng.run()
                best = min(best, r.t_first - t0)
            _row(f"prefix_ttft_{label}", best * 1e6,
                 f"{1e3 * best:.1f}ms to first token")


# --- Speculative decoding: accepted drafts per tick + tok/s -----------------

def bench_spec(fast=False):
    """Self-speculative decoding inside the fused tick.

    Deterministic row: engine runs with ZERO parameters, so every verify
    logit row is identical and greedy emits token 0 forever — the drafter
    proposes all-0 windows (repeat-last fallback, then the tabled 0->0
    transition) and every draft is accepted.  The drafted/accepted/tick
    counts are then pure scheduling arithmetic (window d+1 tokens per
    tick, budget-clamped tail), platform-exact, gating the accept rule
    and the rollback-free fast path.  Wall rows: real parameters on
    repetitive prompts, speculation on vs off at equal traffic, with the
    on/off greedy streams asserted bit-identical in the same record."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime.serve import Engine

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    slots, max_seq, T, d = 2, 64, 25, 4
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    with Engine(cfg, zeros, num_slots=slots, max_seq=max_seq,
                draft_len=d) as eng:
        reqs = [eng.submit([3, 1, 4, 1, 5, 9], T, seed=0)
                for _ in range(slots)]
        eng.run()
        st = eng.spec_stats()
        toks = sum(len(r.out_tokens) for r in reqs)
        _row(f"spec_accept_s{slots}_d{d}_t{T}", 0.0,
             f"acc={st['accepted']}/{st['drafted']} tokens={toks} "
             f"ticks={eng.n_ticks} tok/tick={toks / eng.n_ticks:.1f}",
             deterministic=True)
    # wall rows: real params, repetitive prompts so the n-gram drafter
    # lands real acceptance, spec off vs on at identical traffic
    rng = np.random.default_rng(0)
    R = 2 if fast else 4
    prompts = [np.asarray(list(rng.integers(1, cfg.vocab_size, 5)) * 3,
                          np.int32) for _ in range(R)]
    stats = {}
    for label, dl in (("off", 0), ("on", d)):
        with Engine(cfg, params, num_slots=slots, max_seq=max_seq,
                    draft_len=dl) as eng:
            eng.submit(prompts[0][:4], 3)            # compile warmup
            eng.run()
            dt = float("inf")
            for _ in range(3):
                reqs = [eng.submit(p, T, seed=i)
                        for i, p in enumerate(prompts)]
                t0 = time.perf_counter()
                eng.run()
                dt = min(dt, time.perf_counter() - t0)
            toks = sum(len(r.out_tokens) for r in reqs)
            st = eng.spec_stats()
            stats[label] = {"streams": [r.out_tokens for r in reqs],
                            "ticks": eng.n_ticks}
            extra = (f" acc_rate={st['acceptance_rate']:.2f}"
                     if dl else "")
            _row(f"serve_spec_{label}_s{slots}_r{R}x{T}", dt * 1e6 / toks,
                 f"{toks / dt:.0f} tok/s{extra}")
    _row(f"spec_parity_s{slots}_r{R}x{T}", 0.0,
         f"streams_equal="
         f"{stats['off']['streams'] == stats['on']['streams']}",
         deterministic=True)

    # Per-drafter acceptance on a structured but NON-repetitive stream:
    # layers all zero (the residual passes the embedding through),
    # embedding[t] = onehot(t % d_model), unembed[i, (i+1) % d_model]
    # = 1 — greedy continues t -> t+1 (mod d_model), so every n-gram
    # context is fresh (the table drafter accepts nothing) while the
    # 2-bit draft model replays the verify rule exactly ({0, 1} weights
    # and one-hot activations quantize losslessly).  Counters are pure
    # scheduling arithmetic -> deterministic rows.
    D, V = cfg.d_model, cfg.vocab_size
    struct = jax.tree_util.tree_map(jnp.zeros_like, params)
    emb = jnp.zeros((V, D)).at[jnp.arange(V), jnp.arange(V) % D].set(1.0)
    unemb = jnp.zeros((D, V)).at[jnp.arange(D),
                                 (jnp.arange(D) + 1) % D].set(1.0)
    struct["embed"]["embedding"] = emb.astype(cfg.compute_dtype)
    struct["embed"]["unembed"] = unemb.astype(cfg.compute_dtype)
    struct["final_norm"] = jax.tree_util.tree_map(
        jnp.ones_like, struct["final_norm"])
    acc = {}
    for kind in ("ngram", "model"):
        with Engine(cfg, struct, num_slots=slots, max_seq=max_seq,
                    draft_len=d, drafter=kind) as eng:
            reqs = [eng.submit([1, 2, 3], T, seed=0)
                    for _ in range(slots)]
            eng.run()
            st = eng.spec_stats()
            acc[kind] = st["accepted"] / eng.n_ticks
            _row(f"spec_drafter_{kind}_s{slots}_d{d}_t{T}", 0.0,
                 f"acc={st['accepted']}/{st['drafted']} "
                 f"ticks={eng.n_ticks} acc/tick={acc[kind]:.2f} "
                 f"syncs/tick={eng.n_syncs / eng.n_ticks:.0f}",
                 deterministic=True)
    _row(f"spec_drafter_model_vs_ngram_s{slots}_d{d}_t{T}", 0.0,
         f"model_acc/tick={acc['model']:.2f} "
         f"ngram_acc/tick={acc['ngram']:.2f} "
         f"model_gt_ngram={acc['model'] > acc['ngram']}",
         deterministic=True)
    # drafting-overhead wall row: identical structured traffic with the
    # model drafter on vs speculation off — the per-token delta is the
    # cost of the 2-bit draft forwards net of accepted-window savings.
    wall = {}
    for label, kw in (("off", {"draft_len": 0}),
                      ("model", {"draft_len": d, "drafter": "model"})):
        with Engine(cfg, struct, num_slots=slots, max_seq=max_seq,
                    **kw) as eng:
            eng.submit([1, 2], 3)                    # compile warmup
            eng.run()
            dt = float("inf")
            for _ in range(3):
                reqs = [eng.submit([1, 2, 3], T, seed=0)
                        for _ in range(slots)]
                t0 = time.perf_counter()
                eng.run()
                dt = min(dt, time.perf_counter() - t0)
            toks = sum(len(r.out_tokens) for r in reqs)
            wall[label] = dt / toks
    _row(f"spec_draft_overhead_s{slots}_d{d}_t{T}",
         wall["model"] * 1e6,
         f"model={1 / wall['model']:.0f} tok/s "
         f"off={1 / wall['off']:.0f} tok/s "
         f"overhead={wall['model'] / wall['off']:.2f}x")


# --- Disaggregated prefill/decode: page handoff vs colocated ----------------

def bench_disagg(fast=False):
    """Disaggregated prefill/decode serving vs the colocated engine at
    equal traffic: wall-time tok/s and mean TTFT for both modes, plus a
    deterministic record asserting (a) greedy streams are bit-identical
    across the page handoff, and (b) the handoff itself is exactly
    reproducible — pages transferred, transfer rounds and the decode
    pool's pages-in-use high-water are fixed integers for the fixed
    schedule (the I7 discipline: lowest-free-id grants replayed by the
    decode-side HostPool mirror)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime.serve import Engine

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    R, T = (4, 13) if fast else (8, 13)
    slots, max_seq, dsteps = 4, 64, 4
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 20)))
               for _ in range(R)]
    stats = {}
    for name in ("colocated", "disagg"):
        kw = {"disagg": True} if name == "disagg" else {}
        with Engine(cfg, params, num_slots=slots, max_seq=max_seq,
                    decode_steps=dsteps, kv_layout="paged", **kw) as eng:
            eng.submit(prompts[0][:4], dsteps + 1)     # compile warmup
            eng.run()
            dt = float("inf")
            for _ in range(3):
                eng.pages_high_water = eng.pages_in_use
                if name == "disagg":
                    eng.pages_transferred = eng.transfer_rounds = 0
                reqs = [eng.submit(p, T) for p in prompts]
                t0 = time.perf_counter()
                eng.run()
                dt = min(dt, time.perf_counter() - t0)
            toks = sum(len(r.out_tokens) for r in reqs)
            ttft = sum(r.t_first - r.t_submit for r in reqs) / len(reqs)
            stats[name] = {"streams": [r.out_tokens for r in reqs],
                           "hw": eng.pages_high_water,
                           "pages": eng.num_pages,
                           "moved": getattr(eng, "pages_transferred", 0),
                           "rounds": getattr(eng, "transfer_rounds", 0)}
            _row(f"disagg_{name}_s{slots}_n{dsteps}_r{R}x{T}",
                 dt * 1e6 / toks,
                 f"{toks / dt:.0f} tok/s ttft={ttft * 1e3:.2f}ms")
    c, g = stats["colocated"], stats["disagg"]
    _row(f"disagg_handoff_s{slots}_r{R}x{T}", 0.0,
         f"streams_equal={c['streams'] == g['streams']} "
         f"transferred={g['moved']} pages in {g['rounds']} rounds "
         f"decode_highwater={g['hw']}/{g['pages']} pages",
         deterministic=True)


# --- Dry-run roofline summary (reads results if present) --------------------

def bench_roofline():
    import glob
    import json
    import os

    files = sorted(glob.glob("results/dryrun/*__pod.json"))
    if not files:
        _row("roofline_table", 0.0, "no dry-run results yet "
             "(run python -m repro.launch.dryrun)", record=False)
        return
    for f in files:
        rec = json.load(open(f))
        tag = os.path.basename(f).replace("__pod.json", "")
        if rec.get("status") != "ok":
            _row(f"roofline_{tag}", 0.0, rec.get("status"),
                 record=False)
            continue
        r = rec["roofline"]
        _row(f"roofline_{tag}", rec.get("compile_s", 0) * 1e6,
             f"dominant={r['dominant']} frac={r['roofline_fraction']:.2f} "
             f"useful={r['useful_ratio']:.2f}", record=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller kernel shapes")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench group names to run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write machine-readable records to PATH")
    ap.add_argument("--list", action="store_true",
                    help="print bench group names (valid --only values) "
                         "and exit")
    args, _ = ap.parse_known_args()

    benches = {
        "table2": bench_table2, "fig7": bench_fig7, "fig9": bench_fig9,
        "fig10": bench_fig10, "fig11": bench_fig11,
        "fig13": lambda: bench_fig13(args.fast),
        "kernels": lambda: bench_kernels(args.fast),
        "tp": lambda: bench_tp(args.fast),
        "ep": lambda: bench_ep(args.fast),
        "ep_dispatch": lambda: bench_ep_dispatch(args.fast),
        "serve": lambda: bench_serve(args.fast),
        "paged": lambda: bench_paged(args.fast),
        "prefix": lambda: bench_prefix(args.fast),
        "spec": lambda: bench_spec(args.fast),
        "disagg": lambda: bench_disagg(args.fast),
        "roofline": bench_roofline,
    }
    if args.list:
        print("\n".join(benches))
        return
    print("name,us_per_call,derived")
    only = None
    if args.only:
        only = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = only - set(benches)
        if unknown:
            ap.error(f"unknown bench name(s): {', '.join(sorted(unknown))}"
                     f" (choose from {', '.join(benches)})")
    for name, fn in benches.items():
        if only is not None and name not in only:
            continue
        fn()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"fast": args.fast, "only": args.only,
                       "records": RECORDS}, fh, indent=1)
            fh.write("\n")


if __name__ == "__main__":
    main()
