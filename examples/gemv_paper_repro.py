"""Reproduce the paper's evaluation figures from the analytical models.

    PYTHONPATH=src python examples/gemv_paper_repro.py

Prints Table II, the Fig 9 throughput table, Fig 10 utilization curves,
the Fig 11 speedup heatmap (2-bit persistent), and the Fig 13 DLA summary.
"""
import numpy as np

from repro.core import arch_models as am
from repro.core import gemv_model as gm
from repro.core.efsm import BRAMAC_1DA, BRAMAC_2SA


def table2():
    print("=== Table II ===")
    for v in (BRAMAC_2SA, BRAMAC_1DA):
        lat = "/".join(str(v.mac2_latency(b)) for b in (2, 4, 8))
        par = "/".join(str(v.macs_in_parallel(b)) for b in (2, 4, 8))
        print(f"  {v.name}: {par} MACs in parallel, {lat} cycle latency, "
              f"{v.block_area_overhead:.1%} block / "
              f"{v.core_area_overhead:.1%} core area overhead")


def fig9():
    print("=== Fig 9: peak MAC throughput (TMAC/s) ===")
    for bits in (2, 4, 8):
        base = am.peak_throughput(bits)["total"] / 1e12
        row = [f"baseline {base:5.1f}"]
        for arch in (BRAMAC_2SA, BRAMAC_1DA, am.CCB, am.COMEFA_D,
                     am.COMEFA_A):
            tot = am.peak_throughput(bits, arch)["total"] / 1e12
            row.append(f"{arch.name} {tot:5.1f} ({tot / base:.2f}x)")
        print(f"  {bits}-bit: " + " | ".join(row))


def fig10():
    print("=== Fig 10: BRAM utilization efficiency ===")
    t = am.utilization_table()
    ps = list(range(2, 9))
    for name, vals in t.items():
        print(f"  {name:11s}: " +
              " ".join(f"{p}b={v:.2f}" for p, v in zip(ps, vals)))
    adv = am.utilization_advantage()
    print(f"  avg advantage: {adv['vs_ccb']:.2f}x vs CCB (paper 1.3x), "
          f"{adv['vs_comefa']:.2f}x vs CoMeFa (paper 1.1x)")


def fig11():
    print("=== Fig 11: BRAMAC-1DA GEMV speedup over CCB-Pack-4 "
          "(2-bit persistent) ===")
    grid = gm.speedup_grid(2, persistent=True)
    cols = gm.COL_SIZES
    print("      C=" + "".join(f"{c:>7}" for c in cols))
    for r in gm.ROW_SIZES:
        print(f"  R={r:4d} " + "".join(f"{grid[(r, c)]:7.2f}" for c in cols))
    ms = gm.max_speedups()
    print("  up-to: " + ", ".join(
        f"{k[1]}b-{k[0][:7]} {v:.2f}x" for k, v in sorted(ms.items())))


def fig13():
    from repro.core.dla_model import average_speedups, case_study
    print("=== Fig 13: DLA-BRAMAC case study (avg over 2/4/8-bit) ===")
    for (model, vname), row in average_speedups(case_study()).items():
        print(f"  {model:9s} {vname}: {row['speedup']:.2f}x speedup at "
              f"{row['rel_area']:.2f}x DSP+BRAM area")


if __name__ == "__main__":
    table2()
    fig9()
    fig10()
    fig11()
    fig13()
