"""End-to-end training driver: a ~100M-parameter granite-family model
trained for a few hundred steps on the synthetic Markov LM stream, with
checkpointing, restart, and (optionally) the BRAMAC QAT path.

    PYTHONPATH=src python examples/train_tiny_lm.py \
        [--steps 300] [--quant] [--params-100m]

On the default (CI-sized) config this takes a couple of minutes on CPU;
--params-100m selects the genuine ~100M-parameter model for a longer run.
Loss must drop well below the uniform baseline ln(vocab)≈5.5 — the stream
is an order-1 Markov chain, so a converged model approaches its entropy.
"""
import argparse
import os
import time

import jax

from repro.configs.base import ModelConfig
from repro.core.bramac_linear import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def build_cfg(params_100m: bool, quant: bool) -> ModelConfig:
    if params_100m:     # ~104M params: 12L, d=768, llama-style
        cfg = ModelConfig(
            name="tiny-lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=8192,
            layer_pattern=("attn+dense",), dtype="float32")
    else:               # CI-sized
        cfg = ModelConfig(
            name="tiny-lm", family="dense", num_layers=4, d_model=256,
            num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=512,
            layer_pattern=("attn+dense",), dtype="float32")
    if quant:
        cfg = cfg.replace(quant=QuantConfig(enabled=True, bits_w=8, bits_a=8))
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quant", action="store_true",
                    help="train through the BRAMAC int8 QAT path")
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/bramac_tiny_lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build_cfg(args.params_100m, args.quant)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda k: M.init_params(cfg, k),
                       jax.ShapeDtypeStruct((2,), jax.numpy.uint32))))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"quant={'int8 QAT' if args.quant else 'off'}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100,
                         async_ckpt=True,
                         opt=adamw.AdamWConfig(lr=1e-3, weight_decay=0.01))
    trainer = Trainer(cfg, tcfg, params)
    resumed = trainer.restore_latest()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")

    pipe = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    t0 = time.time()
    hist = trainer.train(pipe, args.steps)
    dt = time.time() - t0
    if hist:
        first = sum(h["loss"] for h in hist[:5]) / max(len(hist[:5]), 1)
        last = sum(h["loss"] for h in hist[-5:]) / max(len(hist[-5:]), 1)
        tok_s = args.batch * args.seq * len(hist) / dt
        print(f"steps {trainer.step}: loss {first:.3f} -> {last:.3f} "
              f"({tok_s:.0f} tok/s)")
        assert last < first, "loss did not decrease"
    print(f"checkpoints in {args.ckpt_dir}: kept "
          f"{sorted(os.listdir(args.ckpt_dir))[-1]}")


if __name__ == "__main__":
    main()
