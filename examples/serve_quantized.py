"""Batched serving with BRAMAC-quantized execution — the paper's
tiling-based inference deployment (§VI) on the serving engine.

    PYTHONPATH=src python examples/serve_quantized.py [--bits 4]

Loads a small model, serves a batch of prompts twice — fp32 and through
the BRAMAC int-quantized QAT path — and reports agreement + tokens/s.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.bramac_linear import QuantConfig
from repro.models import model as M
from repro.runtime.serve import Engine


def run(cfg, params, prompts, new_tokens):
    with Engine(cfg, params, num_slots=4, max_seq=96,
                decode_steps=4) as eng:
        reqs = [eng.submit(p, new_tokens) for p in prompts]
        t0 = time.time()
        eng.run()
        dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    return [r.out_tokens for r in reqs], toks / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=8, choices=(2, 4, 8))
    args = ap.parse_args()

    cfg = get_config("granite-8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in
               (9, 17, 5, 24, 12, 7)]

    fp_out, fp_tps = run(cfg, params, prompts, new_tokens=8)
    qcfg = cfg.replace(quant=QuantConfig(enabled=True, bits_w=args.bits,
                                         bits_a=args.bits))
    q_out, q_tps = run(qcfg, params, prompts, new_tokens=8)

    agree = np.mean([np.mean(np.array(a) == np.array(b))
                     for a, b in zip(fp_out, q_out)])
    print(f"served {len(prompts)} prompts x 8 tokens")
    print(f"  fp32 path: {fp_tps:.1f} tok/s")
    print(f"  BRAMAC int{args.bits} path: {q_tps:.1f} tok/s")
    print(f"  greedy-token agreement int{args.bits} vs fp32: {agree:.2%}")


if __name__ == "__main__":
    main()
