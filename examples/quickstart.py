"""Quickstart: BRAMAC's MAC2 algorithm, the quantized matmul kernel, and a
quantized model forward pass — in two minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import mac2
from repro.core.bramac_linear import QuantConfig
from repro.kernels import ops, ref
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")


def demo_mac2():
    print("=== Algorithm 1: hybrid bit-serial & bit-parallel MAC2 ===")
    w1, w2, i1, i2 = -3, 7, -5, 2
    p = int(mac2.mac2(jnp.int32(w1), jnp.int32(w2), i1, i2, bits=4))
    print(f"  W1*I1 + W2*I2 = {w1}*{i1} + {w2}*{i2} = {p} "
          f"(oracle {w1 * i1 + w2 * i2})")

    rng = np.random.default_rng(0)
    w = rng.integers(-8, 8, (8, 6)).astype(np.int8)      # Fig 2's 8x6 matrix
    x = rng.integers(-8, 8, (6,)).astype(np.int8)
    y = mac2.mac2_mvm(jnp.asarray(w), jnp.asarray(x), bits=4)
    print(f"  Fig 2 MVM via chained MAC2s: max|err| = "
          f"{np.abs(np.asarray(y) - w.astype(np.int32) @ x).max()}")


def demo_kernel():
    print("=== BRAMAC radix-4 quantized matmul (Pallas, interpret) ===")
    rng = np.random.default_rng(1)
    for bits in (2, 4, 8):
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        xq = jnp.asarray(rng.integers(lo, hi + 1, (32, 64), dtype=np.int8))
        wq = jnp.asarray(rng.integers(lo, hi + 1, (64, 32), dtype=np.int8))
        one = jnp.ones((1, 1), jnp.float32)
        out = ops.quant_matmul(xq, wq, one, one, bits_a=bits, bits_w=bits)
        want = ref.quant_matmul_exact(xq, wq, one, one)
        print(f"  {bits}-bit ({(bits + 1) // 2} digit pass(es)): "
              f"max|err| = {float(jnp.max(jnp.abs(out - want)))}")


def demo_quantized_model():
    print("=== granite-8b (smoke config) with BRAMAC 8-bit QAT path ===")
    cfg = get_config("granite-8b", smoke=True).replace(
        quant=QuantConfig(enabled=True, bits_w=8, bits_a=8))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits, _, _ = M.forward(params, {"tokens": tokens}, cfg)
    fp_cfg = cfg.replace(quant=QuantConfig(enabled=False))
    fp_logits, _, _ = M.forward(params, {"tokens": tokens}, fp_cfg)
    cos = float(jnp.sum(logits * fp_logits) /
                (jnp.linalg.norm(logits) * jnp.linalg.norm(fp_logits)))
    print(f"  logits shape {logits.shape}; cosine(int8, fp) = {cos:.4f}")


if __name__ == "__main__":
    demo_mac2()
    demo_kernel()
    demo_quantized_model()
