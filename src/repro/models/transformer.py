"""Layer stack: pattern-driven blocks, scan over periods, caches, remat.

A block is `<mixer>+<ff>` (configs/base.py).  Parameters of position i in
the repeating pattern are stacked over the `n_periods` scan axis, so a
72-layer model lowers as one scanned period — compact HLO, fast dry-run
compiles, and the FSDP all-gather of each period's params happens inside
the scan (overlappable by the XLA latency-hiding scheduler).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import init_mlp, init_rmsnorm, mlp, rmsnorm
from repro.parallel.sharding import constrain

MIXERS = ("attn", "mla", "xattn", "mamba", "mlstm", "slstm")
FFS = ("dense", "moe", "none")


def parse_spec(spec: str) -> tuple[str, str]:
    mixer, ff = spec.split("+")
    assert mixer in MIXERS and ff in FFS, spec
    return mixer, ff


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def init_block(key, cfg, spec: str):
    mixer, ff = parse_spec(spec)
    k1, k2 = jax.random.split(key)
    dt = cfg.compute_dtype
    p: dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dt)}
    if mixer == "attn":
        p["mixer"] = attn.init_gqa(k1, cfg)
    elif mixer == "mla":
        p["mixer"] = attn.init_mla(k1, cfg)
    elif mixer == "xattn":
        p["mixer"] = attn.init_xattn(k1, cfg)
    elif mixer == "mamba":
        p["mixer"] = mb.init_mamba(k1, cfg)
    elif mixer == "mlstm":
        p["mixer"] = xl.init_mlstm(k1, cfg)
    elif mixer == "slstm":
        p["mixer"] = xl.init_slstm(k1, cfg)
    if ff != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dt)
        p["moe" if ff == "moe" else "mlp"] = (
            moe_mod.init_moe(k2, cfg) if ff == "moe"
            else init_mlp(k2, cfg.d_model, cfg.d_ff, dt))
    return p


def init_block_cache(cfg, spec: str, batch: int, max_seq: int, dtype,
                     num_pages=None):
    """Decode-time state for one block (None for stateless).

    num_pages switches attention KV to the paged pool layout; recurrent
    state and the cross-attention cache are per-slot fixed-size arrays
    either way (they are the "registers" of a slot, not token storage)."""
    mixer, _ = parse_spec(spec)
    if mixer == "attn":
        return attn.init_gqa_cache(cfg, batch, max_seq, dtype, num_pages)
    if mixer == "mla":
        return attn.init_mla_cache(cfg, batch, max_seq, dtype, num_pages)
    if mixer == "xattn":
        return attn.init_xattn_cache(cfg, batch, dtype)
    if mixer == "mamba":
        return mb.init_mamba_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return xl.init_mlstm_state(cfg, batch)
    if mixer == "slstm":
        return xl.init_slstm_state(cfg, batch)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# per-block apply
# ---------------------------------------------------------------------------

def block_apply(p, x, cfg, spec, *, positions, vision_embeds=None,
                cache=None, cache_pos=None, paged=None):
    """Returns (x, aux_loss, new_cache).

    `paged` (an attention.PagedKV bundle, threaded untouched from the
    engine) selects the paged KV discipline inside gqa/mla — including,
    when its decode_kernel flag is set, the pallas block-table decode
    kernel for Sq=1 gqa reads (mla always takes the gather oracle)."""
    mixer, ff = parse_spec(spec)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    decode = cache is not None and x.shape[1] == 1
    if mixer == "attn":
        y, new_cache = attn.gqa(p["mixer"], h, cfg, positions, cache,
                                cache_pos, paged)
    elif mixer == "mla":
        y, new_cache = attn.mla(p["mixer"], h, cfg, positions, cache,
                                cache_pos, paged)
    elif mixer == "xattn":
        y, new_cache = attn.xattn(p["mixer"], h, cfg, vision_embeds,
                                  cache, cache_pos)
    elif mixer == "mamba":
        if decode:
            y, new_cache = mb.mamba_step(p["mixer"], h, cfg, cache)
        else:
            y, new_cache = mb.mamba_sequence(p["mixer"], h, cfg)
    elif mixer == "mlstm":
        if decode:
            y, new_cache = xl.mlstm_step(p["mixer"], h, cfg, cache)
        else:
            y, new_cache = xl.mlstm_sequence(p["mixer"], h, cfg)
    elif mixer == "slstm":
        if decode:
            y, new_cache = xl.slstm_step(p["mixer"], h, cfg, cache)
        else:
            y, new_cache = xl.slstm_sequence(p["mixer"], h, cfg)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ff == "dense":
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg.quant)
    elif ff == "moe":
        y, aux = moe_mod.moe(p["moe"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                             cfg)
        x = x + y
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def init_stack(key, cfg):
    """{"pos{i}": stacked-over-periods block params}"""
    params = {}
    for i, spec in enumerate(cfg.layer_pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), cfg.n_periods)
        params[f"pos{i}"] = jax.vmap(
            lambda k: init_block(k, cfg, spec))(keys)
    return params


def init_stack_cache(cfg, batch, max_seq, dtype, num_pages=None):
    caches = {}
    for i, spec in enumerate(cfg.layer_pattern):
        one = init_block_cache(cfg, spec, batch, max_seq, dtype, num_pages)
        caches[f"pos{i}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(),
            one)
    return caches


def stack_cache_pool_flags(cfg):
    """A pytree matching init_stack_cache's paged structure with True at
    shared page-pool leaves and False at per-slot leaves — engines use it
    to reset/merge only slot-private state (pools are co-owned and must
    never be blanket-reset or slot-masked), and `runtime.pages.cow_copy`
    uses it to route copy-on-write page splits to every pool leaf (each
    stacked leaf is (n_periods, num_pages, page_size, ...) — the page
    axis is axis 1) while leaving per-slot leaves untouched."""
    flags = {}
    for i, spec in enumerate(cfg.layer_pattern):
        mixer, _ = parse_spec(spec)
        is_pool = mixer in ("attn", "mla")
        shapes = jax.eval_shape(
            lambda s=spec: init_block_cache(cfg, s, 1, cfg.page_size,
                                            cfg.compute_dtype, num_pages=1))
        flags[f"pos{i}"] = jax.tree_util.tree_map(lambda _: is_pool, shapes)
    return flags


def stack_apply(params, x, cfg, *, positions, vision_embeds=None,
                caches=None, cache_pos=None, paged=None):
    """Scan over periods. Returns (x, aux_total, new_caches)."""

    def period(x, layer_in):
        p_slice, cache_slice = layer_in
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, spec in enumerate(cfg.layer_pattern):
            cache_i = None if cache_slice is None else cache_slice[f"pos{i}"]
            x, aux, nc = block_apply(
                p_slice[f"pos{i}"], x, cfg, spec, positions=positions,
                vision_embeds=vision_embeds, cache=cache_i,
                cache_pos=cache_pos, paged=paged)
            aux_total += aux
            if nc is not None:
                new_caches[f"pos{i}"] = nc
        x = constrain(x, "batch", "act_seq", None)
        return x, (aux_total, new_caches if new_caches else None)

    body = period
    if cfg.remat:
        body = jax.checkpoint(period,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(carry, layer_in):
        x, aux_acc = carry
        x, (aux, new_caches) = body(x, layer_in)
        return (x, aux_acc + aux), new_caches

    xs = (params, caches)
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), xs)
        return x, aux, new_caches

    # unrolled (python-loop) stack: identical computation, every period in
    # the HLO — used by the dry-run's cost probe (scan bodies are counted
    # once by XLA cost analysis) and available as a runtime choice.
    carry = (x, jnp.zeros((), jnp.float32))
    out_caches = []
    for i in range(cfg.n_periods):
        layer_in = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, nc = scan_body(carry, layer_in)
        out_caches.append(nc)
    (x, aux) = carry
    if out_caches and out_caches[0] is not None:
        new_caches = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *out_caches)
    else:
        new_caches = None
    return x, aux, new_caches
