"""Top-level model API: init / train forward / loss / prefill / decode.

Batch dict conventions (ShapeDtypeStruct stand-ins come from
launch.input_specs with identical structure):

  LM / code / dense / moe : {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm   : + {"vision_embeds": (B, T_v, D)}   (stub frontend)
  audio : {"frame_embeds": (B,S,D), "labels": (B,S)}  (stub EnCodec)

Serving:
  prefill(params, batch, cache)   — writes the cache, returns last logits
  decode_step(params, tokens, cache, pos) — one token for every sequence
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.layers import cross_entropy, embed, init_embed, init_rmsnorm, \
    rmsnorm, unembed
from repro.parallel.sharding import constrain


def init_params(cfg: ModelConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {"embed": init_embed(k1, cfg.vocab_size, cfg.d_model,
                                cfg.compute_dtype),
            "final_norm": init_rmsnorm(cfg.d_model, cfg.compute_dtype),
            "layers": tf.init_stack(k2, cfg)}


def _inputs_to_hidden(params, batch, cfg):
    if "frame_embeds" in batch:                      # audio stub frontend
        x = batch["frame_embeds"].astype(cfg.compute_dtype)
    else:
        x = embed(params["embed"], batch["tokens"])
    return constrain(x, "batch", None, None)


def forward(params, batch, cfg: ModelConfig, caches=None, cache_pos=None,
            last_only: bool = False, gather_pos=None, paged=None):
    """Returns (logits, aux_loss, new_caches).

    last_only: unembed only the final position — prefill at 32k would
    otherwise materialize a (B, 32768, vocab) logits tensor.
    gather_pos: (B,) per-sequence position to unembed instead (chunked
    prefill: each slot's true last prompt token sits at a different row);
    returns (B, 1, vocab) logits like last_only.
    paged: an attention.PagedKV bundle — caches hold shared page pools
    instead of dense per-sequence reservations, and attention
    gathers/scatters KV rows through its block tables.  The bundle's
    block tables / refcounts / ownership bits come from the engine's
    `runtime.pages.PagePool` allocator state: entries mapped read-only
    (prefix-cache shares) carry owned=False, and the paged scatter drops
    their writes so shared pages are never corrupted.  A bundle with
    decode_kernel=True additionally routes S=1 gqa reads through the
    pallas paged-decode kernel (kernels/paged_attention.py) — per-step
    traffic bounded by each sequence's live pages, never max_seq; mla
    and S>1 chunks keep the gather oracle."""
    x = _inputs_to_hidden(params, batch, cfg)
    B, S = x.shape[:2]
    if cache_pos is not None:
        # serving: absolute positions start at each sequence's cache_pos —
        # S == 1 is a decode step, S > 1 a (possibly offset) prefill chunk
        positions = cache_pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    ve = batch.get("vision_embeds")
    if ve is not None:
        ve = ve.astype(cfg.compute_dtype)
    x, aux, new_caches = tf.stack_apply(
        params["layers"], x, cfg, positions=positions, vision_embeds=ve,
        caches=caches, cache_pos=cache_pos, paged=paged)
    if last_only:
        x = x[:, -1:]
    elif gather_pos is not None:
        x = jnp.take_along_axis(x, gather_pos[:, None, None], axis=1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.quant)
    return constrain(logits, "batch", None, "tp"), aux, new_caches


def loss_fn(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    logits, aux, _ = forward(params, batch, cfg)
    loss = cross_entropy(logits, batch["labels"])
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, num_pages=None):
    """num_pages=None: dense [batch, max_seq] KV reservations.  Otherwise
    attention KV lives in a shared pool of `num_pages` pages of
    `cfg.page_size` rows each (block tables, refcounts and page ownership
    are engine state — a `runtime.pages.PagePool` — passed to
    forward/decode_step as an attention.PagedKV bundle)."""
    return tf.init_stack_cache(cfg, batch, max_seq, cfg.compute_dtype,
                               num_pages)


def cache_pool_flags(cfg: ModelConfig):
    """Pytree matching init_cache(num_pages=...) with True at shared-pool
    leaves, False at per-slot leaves (recurrent state, xattn KV)."""
    return tf.stack_cache_pool_flags(cfg)


def prefill(params, batch, cfg: ModelConfig, caches):
    """Run the prompt through the model, filling the cache.

    Returns (last_token_logits (B,V), new_caches)."""
    B, S = _batch_bs(batch, cfg)
    cache_pos = jnp.zeros((B,), jnp.int32)      # prefill writes from 0
    logits, _, new_caches = forward(params, batch, cfg, caches, cache_pos,
                                    last_only=True)
    return logits[:, -1], new_caches


def decode_step(params, tokens, cfg: ModelConfig, caches, pos, paged=None):
    """tokens: (B,1) i32; pos: (B,) current position (index being written).

    Returns (logits (B,V), new_caches)."""
    batch = {"tokens": tokens}
    logits, _, new_caches = forward(params, batch, cfg, caches, cache_pos=pos,
                                    paged=paged)
    return logits[:, 0], new_caches


def _batch_bs(batch, cfg):
    if "frame_embeds" in batch:
        return batch["frame_embeds"].shape[:2]
    return batch["tokens"].shape[:2]
