"""Attention mixers: GQA (+RoPE), MLA (latent attention), cross-attention.

All support three modes driven by the call:
  * train/prefill: full causal attention, query-chunked (online softmax per
    chunk is unnecessary — chunking the query axis alone bounds the score
    matrix at (B, H, chunk, S), which is what fits VMEM/HBM at 32k).
  * decode: single-token query against a KV cache updated in place.

Caches are plain dicts of arrays so they shard/checkpoint like params.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, init_dense, init_rmsnorm, rmsnorm

Q_CHUNK = 1024


# ---------------------------------------------------------------------------
# core softmax attention
# ---------------------------------------------------------------------------

def _attend(q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Sk,Hkv,hd); mask: (Sq,Sk) or (B,1,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Sq, Hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, :, None]                      # (B,1,1,Sq,Sk)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])   # v head dim may differ (MLA)


def causal_attention(q, k, v, q_offset=0):
    """Query-chunked causal attention (training / prefill)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if Sq <= Q_CHUNK:
        mask = (jnp.arange(Sk)[None, :] <=
                (jnp.arange(Sq)[:, None] + q_offset))
        return _attend(q, k, v, mask)
    n_chunks = Sq // Q_CHUNK
    assert Sq % Q_CHUNK == 0, "sequence must be divisible by Q_CHUNK"
    qc = q.reshape(B, n_chunks, Q_CHUNK, H, hd).swapaxes(0, 1)

    def body(i, qi):
        offs = q_offset + i * Q_CHUNK
        mask = (jnp.arange(Sk)[None, :] <=
                (jnp.arange(Q_CHUNK)[:, None] + offs))
        return _attend(qi, k, v, mask)

    out = jax.lax.map(lambda args: body(*args),
                      (jnp.arange(n_chunks), qc))
    return out.swapaxes(0, 1).reshape(B, Sq, H, v.shape[-1])


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode: the pos-masked cache attention of
    chunk_attention at Sq=1.  q: (B,1,H,hd); pos: (B,) current lengths."""
    return chunk_attention(q, k_cache, v_cache, pos[:, None])


def chunk_attention(q, k_cache, v_cache, positions):
    """Causal attention of a prefill chunk at an arbitrary offset.

    q: (B,Sq,H,hd) chunk queries; caches: (B,S_max,Hkv,hd) already updated
    with this chunk's K/V; positions: (B,Sq) absolute query positions.
    Each query row attends every cache row at or before its own position —
    at offset 0 this reduces to plain causal prefill (rows past the chunk
    are masked to exact zeros), and at offset>0 it sees all earlier chunks."""
    Sk = k_cache.shape[1]
    mask = jnp.arange(Sk)[None, None, :] <= positions[:, :, None]  # (B,Sq,Sk)
    return _attend(q, k_cache, v_cache, mask[:, None])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg):
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.compute_dtype
    return {"wq": init_dense(ks[0], d, H * hd, dt),
            "wk": init_dense(ks[1], d, Hkv * hd, dt),
            "wv": init_dense(ks[2], d, Hkv * hd, dt),
            "wo": init_dense(ks[3], H * hd, d, dt)}


def gqa(p, x, cfg, positions, cache=None, cache_pos=None):
    """cache: {"k","v"} (B, S_max, Hkv, hd) or None (train/prefill).

    Returns (out, new_cache)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = dense(x, p["wq"], cfg.quant).reshape(B, S, H, hd)
    k = dense(x, p["wk"], cfg.quant).reshape(B, S, Hkv, hd)
    v = dense(x, p["wv"], cfg.quant).reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = causal_attention(q, k, v)
        new_cache = None
    elif "ks" in cache:                          # int8 KV cache (quant_kv)
        # decode and prefill chunks both attend the stored int8 rows
        # (earlier chunks only exist quantized) via the same masked path
        new_cache = _update_cache_q(cache, k, v, cache_pos)
        out = decode_attention_q(q, new_cache, positions)
    else:
        kc = _update_cache(cache["k"], k, cache_pos)
        vc = _update_cache(cache["v"], v, cache_pos)
        # decode (S=1, positions == cache_pos) and prefill chunks share
        # the same masked path over the cache
        out = chunk_attention(q, kc, vc, positions)
        new_cache = {"k": kc, "v": vc}
    return dense(out.reshape(B, S, H * hd), p["wo"], cfg.quant), new_cache


def _update_cache(cache, new, pos):
    """Insert `new` (B,S,…) at per-batch position `pos` (B,)."""
    B, S = new.shape[:2]
    if S == cache.shape[1]:
        return new.astype(cache.dtype)

    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache, new, pos)


def init_gqa_cache(cfg, batch, max_seq, dtype):
    hd = cfg.hd
    shape = (batch, max_seq, cfg.num_kv_heads, hd)
    if getattr(cfg, "quant_kv", False):
        # int8 KV cache (beyond-paper: the paper's integer-MAC dataflow
        # applied to the cache, which dominates decode HBM bytes)
        sshape = (batch, max_seq, cfg.num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "vs": jnp.ones(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# int8 KV cache (quant_kv) — BRAMAC integer arithmetic inside attention
# ---------------------------------------------------------------------------

def _quant_rows(x):
    """Per-(…, head) row int8 quantization over the feature dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _update_cache_q(cache, k, v, pos):
    kq, ks = _quant_rows(k)
    vq, vs = _quant_rows(v)
    return {"k": _update_cache(cache["k"], kq, pos),
            "ks": _update_cache(cache["ks"], ks, pos),
            "v": _update_cache(cache["v"], vq, pos),
            "vs": _update_cache(cache["vs"], vs, pos)}


def decode_attention_q(q, cache, positions):
    """Attention over the int8 cache — decode (Sq=1) and offset prefill
    chunks (Sq=C) alike; positions: (B, Sq) absolute query positions.

    Both dots run int8×int8→int32 on the MXU (the nd=1 endpoint of the
    BRAMAC digit loop): Q is row-quantized on the fly; K's scales factor
    out of the score dot; V's *per-position* scales fold into the
    probabilities elementwise before the PV dot, so V is consumed as
    stored int8 — no dequantized cache copy is ever materialized."""
    B, Sq, H, hd = q.shape
    kc, ks, vc, vs = cache["k"], cache["ks"], cache["v"], cache["vs"]
    Sk, Hkv = kc.shape[1], kc.shape[2]
    group = H // Hkv
    qq, qs = _quant_rows(q)                              # (B,Sq,H,hd),(B,Sq,H)
    qg = qq.reshape(B, Sq, Hkv, group, hd)
    scores_i = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,      # int8 MXU dot
                          preferred_element_type=jnp.int32)
    qs_g = qs.reshape(B, Sq, Hkv, group).transpose(0, 2, 3, 1)  # (B,Hkv,g,Sq)
    scores = scores_i.astype(jnp.float32) \
        * qs_g[..., None] * ks.transpose(0, 2, 1)[:, :, None, None, :]
    scores = scores / math.sqrt(hd)
    mask = (jnp.arange(Sk)[None, None, :]
            <= positions[:, :, None])[:, None, None]     # (B,1,1,Sq,Sk)
    probs = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
    # fold per-position V scales into the probabilities, requantize rows
    pv = probs * vs.transpose(0, 2, 1)[:, :, None, None, :]  # (B,Hkv,g,Sq,Sk)
    pq, pscale = _quant_rows(pv)
    out_i = jnp.einsum("bhgqk,bkhd->bqhgd", pq, vc,
                       preferred_element_type=jnp.int32)
    out = out_i.astype(jnp.float32) \
        * pscale.transpose(0, 3, 1, 2)[..., None]            # (B,Sq,Hkv,g,1)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3/DeepSeek style)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    dt = cfg.compute_dtype
    return {
        "w_dq": init_dense(ks[0], d, qr, dt),
        "q_norm": init_rmsnorm(qr, dt),
        "w_uq": init_dense(ks[1], qr, H * (nope + rope), dt),
        "w_dkv": init_dense(ks[2], d, kvr, dt),
        "kv_norm": init_rmsnorm(kvr, dt),
        "w_kr": init_dense(ks[3], d, rope, dt),
        "w_uk": init_dense(ks[4], kvr, H * nope, dt),
        "w_uv": init_dense(ks[5], kvr, H * vd, dt),
        "wo": init_dense(ks[6], H * vd, d, dt),
    }


def mla(p, x, cfg, positions, cache=None, cache_pos=None):
    """Latent attention; the cache stores only (c_kv, k_rope) — the paper's
    BRAMAC quantization applies to every projection here as well."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = dense(rmsnorm(p["q_norm"], dense(x, p["w_dq"], cfg.quant),
                      cfg.norm_eps), p["w_uq"], cfg.quant)
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(p["kv_norm"], dense(x, p["w_dkv"], cfg.quant), cfg.norm_eps)
    k_rope = apply_rope(dense(x, p["w_kr"], cfg.quant)[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]   # (B,S,rope)

    if cache is not None:
        c_kv = _update_cache(cache["c_kv"], c_kv, cache_pos)
        k_rope = _update_cache(cache["k_rope"], k_rope, cache_pos)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        Sk = c_kv.shape[1]
    else:
        new_cache = None
        Sk = S

    k_nope = dense(c_kv, p["w_uk"], cfg.quant).reshape(B, Sk, H, nope)
    v = dense(c_kv, p["w_uv"], cfg.quant).reshape(B, Sk, H, vd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Sk, H, rope))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    if cache is not None:                        # decode or prefill chunk
        out = chunk_attention(q_full, k, v, positions)
    else:
        out = causal_attention(q_full, k[:, :S], v[:, :S])
    return dense(out.reshape(B, S, H * vd), p["wo"], cfg.quant), new_cache


def init_mla_cache(cfg, batch, max_seq, dtype):
    return {"c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}


# ---------------------------------------------------------------------------
# Cross-attention (VLM: text queries attend to image patch embeddings)
# ---------------------------------------------------------------------------

def init_xattn(key, cfg):
    return init_gqa(key, cfg) | {
        "kv_norm": init_rmsnorm(cfg.d_model, cfg.compute_dtype)}


def xattn(p, x, cfg, vision_embeds, cache=None, cache_pos=None):
    """vision_embeds: (B, T_v, D) precomputed patch embeddings (stub
    frontend per the assignment).  K/V are position-free; for decode the
    projected K/V are cached once."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = dense(x, p["wq"], cfg.quant).reshape(B, S, H, hd)
    decoding = cache is not None and S == 1        # static condition
    if decoding:
        k, v = cache["k"], cache["v"]              # projected during prefill
    else:
        ve = rmsnorm(p["kv_norm"], vision_embeds, cfg.norm_eps)
        Tv = ve.shape[1]
        k = dense(ve, p["wk"], cfg.quant).reshape(B, Tv, Hkv, hd)
        v = dense(ve, p["wv"], cfg.quant).reshape(B, Tv, Hkv, hd)
    mask = jnp.ones((S, k.shape[1]), bool)
    out = _attend(q, k, v, mask)
    new_cache = {"k": k.astype(cache["k"].dtype),
                 "v": v.astype(cache["v"].dtype)} \
        if cache is not None else None
    return dense(out.reshape(B, S, H * hd), p["wo"], cfg.quant), new_cache


def init_xattn_cache(cfg, batch, dtype):
    shape = (batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
