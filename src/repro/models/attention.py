"""Attention mixers: GQA (+RoPE), MLA (latent attention), cross-attention.

All support three modes driven by the call:
  * train/prefill: full causal attention, query-chunked (online softmax per
    chunk is unnecessary — chunking the query axis alone bounds the score
    matrix at (B, H, chunk, S), which is what fits VMEM/HBM at 32k).
  * decode: single-token query against a KV cache updated in place.

Caches are plain dicts of arrays so they shard/checkpoint like params.

KV layouts (the paper's small-fixed-array memory discipline applied to
serving):
  * dense — per-sequence (B, max_seq, …) reservations, updated with
    dynamic_update_slice at cache_pos.
  * paged — one shared pool of (page_size,)-row pages per layer plus
    per-sequence int32 block tables (a `PagedKV` bundle threaded through
    the forward call).  Writes scatter rows through the table (masked
    rows drop out of bounds), reads gather the table back into a
    (B, max_seq, …) view whose masked rows make exactly-zero softmax
    contributions — so the arithmetic is bit-identical to the dense
    layout while pool capacity is bounded by live tokens, not
    num_slots × max_seq.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels import paged_attention as pk
from repro.models.layers import apply_rope, dense, init_dense, init_rmsnorm, rmsnorm

Q_CHUNK = 1024


# ---------------------------------------------------------------------------
# paged KV layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PagedKV:
    """Block-table view of a shared page pool, built *inside* traced code
    (plain dataclass, not a pytree: `max_seq`/`page_size` stay static).

    tables     (B, max_pages) i32 — page id of each sequence's page j;
               unallocated entries may hold any in-range id (their rows are
               only ever read masked).
    n_pages    (B,) i32 — pages actually allocated per sequence; writes to
               positions at or past `n_pages * page_size` are dropped.
    write_mask (B,) bool — sequences allowed to write this call (admitting
               slots during prefill, active slots during decode); a masked
               sequence's rows never reach the pool, so co-resident
               sequences sharing it stay untouched.
    owned      (B, max_pages) bool or None — per-table-entry write
               permission from the refcounted allocator: entries mapped
               read-only (prefix-cache shares) are False and their writes
               are dropped, so a slot can never corrupt a page other
               consumers read.  None (dense-era callers) means every
               allocated entry is writable.
    bound      (B,) i32 or None — per-sequence accepted-length bound for
               speculative decoding: writes at positions >= bound are
               dropped.  The engine sets bound = pos + budget so a draft
               window can never write rows a non-speculative run could
               not reach (and `pages.rollback` honours the same bound).
               None means no extra bound (the non-speculative paths).
    decode_kernel — static bool: route Sq=1 gqa reads through the pallas
               paged-attention kernel (`kernels/paged_attention.py`), which
               walks the block table page by page instead of gathering a
               dense (B, max_seq, …) view.  Writes, mla and Sq>1 chunks
               (prefill, the speculative verify window) always use the
               gather oracle, which stays the parity reference.
    """
    tables: jax.Array
    n_pages: jax.Array
    write_mask: jax.Array
    max_seq: int
    page_size: int
    owned: jax.Array | None = None
    bound: jax.Array | None = None
    decode_kernel: bool = False


@dataclasses.dataclass
class DenseKV:
    """Write discipline for the dense layout when rows are scattered at
    arbitrary per-row positions (the speculative verify chunk) instead of
    one contiguous dynamic_update_slice.  dynamic_update_slice CLAMPS a
    start index that would overflow — a draft window near max_seq would
    silently slide back and scramble earlier valid rows — so speculative
    dense writes go through a per-position scatter that *drops*
    out-of-range rows instead, mirroring `paged_update`'s masking
    (write_mask gates whole sequences; `bound` is the same per-sequence
    accepted-length bound PagedKV carries)."""
    write_mask: jax.Array                       # (B,) bool
    max_seq: int
    bound: jax.Array | None = None              # (B,) i32


def dense_update(cache, new, positions, dv: DenseKV):
    """Scatter `new` (B, S, …) rows into the dense cache (B, max_seq, …)
    at absolute `positions` (B, S); masked / out-of-range rows drop.

    Both bounds matter: a negative position would wrap (`.at[]` follows
    NumPy indexing) and silently alias the tail of a live sequence."""
    ok = dv.write_mask[:, None] & (positions < dv.max_seq) & (positions >= 0)
    if dv.bound is not None:
        ok &= positions < dv.bound[:, None]
    pos = jnp.where(ok, positions, dv.max_seq)  # max_seq is OOB -> dropped
    rows = jnp.arange(positions.shape[0])[:, None]
    return cache.at[rows, pos].set(new.astype(cache.dtype), mode="drop")


def paged_update(pool, new, positions, pv: PagedKV):
    """Scatter `new` (B, S, …) rows at absolute `positions` (B, S) through
    the block table into `pool` ((P, page_size, …)).  Masked / out-of-range
    rows — and rows aimed at a shared (un-owned) page or past the
    speculative bound — are routed to page id P and dropped.

    The lower bound is load-bearing: a negative position floor-divides to a
    negative pg_idx (which passes `< n_pages`), clips to table entry 0, and
    `% page_size` wraps its row positive — without `positions >= 0` a stray
    padding row would land inside a live page."""
    P, ps = pool.shape[0], pv.page_size
    pg_idx = positions // ps
    ok = pv.write_mask[:, None] & (pg_idx < pv.n_pages[:, None]) \
        & (positions < pv.max_seq) & (positions >= 0)
    if pv.owned is not None:
        ok &= jnp.take_along_axis(
            pv.owned, jnp.clip(pg_idx, 0, pv.tables.shape[1] - 1), axis=1)
    if pv.bound is not None:
        ok &= positions < pv.bound[:, None]
    pg = jnp.take_along_axis(
        pv.tables, jnp.clip(pg_idx, 0, pv.tables.shape[1] - 1), axis=1)
    pg = jnp.where(ok, pg, P)                       # OOB page id -> dropped
    return pool.at[pg, positions % ps].set(new.astype(pool.dtype),
                                           mode="drop")


def paged_view(pool, pv: PagedKV):
    """Gather each sequence's pages into a dense (B, max_seq, …) view.

    Unallocated table entries gather garbage rows, but every such row sits
    at a position the causal mask excludes, where `_attend` replaces its
    score with exactly -1e30 — identical to the dense layout's untouched
    rows, so downstream softmax arithmetic is bit-identical."""
    view = pool[jnp.clip(pv.tables, 0, pool.shape[0] - 1)]
    B = pv.tables.shape[0]
    view = view.reshape((B, -1) + pool.shape[2:])
    return view[:, :pv.max_seq]


# ---------------------------------------------------------------------------
# core softmax attention
# ---------------------------------------------------------------------------

def _attend(q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Sk,Hkv,hd); mask: (Sq,Sk) or (B,1,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Sq, Hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, :, None]                      # (B,1,1,Sq,Sk)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])   # v head dim may differ (MLA)


def causal_attention(q, k, v, q_offset=0):
    """Query-chunked causal attention (training / prefill)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if Sq <= Q_CHUNK:
        mask = (jnp.arange(Sk)[None, :] <=
                (jnp.arange(Sq)[:, None] + q_offset))
        return _attend(q, k, v, mask)
    # ragged sequences run the full chunks through the scanned body and the
    # leftover rows (< Q_CHUNK of them) through one extra trailing _attend
    n_chunks = Sq // Q_CHUNK
    Sq_full = n_chunks * Q_CHUNK
    qc = q[:, :Sq_full].reshape(B, n_chunks, Q_CHUNK, H, hd).swapaxes(0, 1)

    def body(i, qi):
        offs = q_offset + i * Q_CHUNK
        mask = (jnp.arange(Sk)[None, :] <=
                (jnp.arange(Q_CHUNK)[:, None] + offs))
        return _attend(qi, k, v, mask)

    out = jax.lax.map(lambda args: body(*args),
                      (jnp.arange(n_chunks), qc))
    out = out.swapaxes(0, 1).reshape(B, Sq_full, H, v.shape[-1])
    if Sq_full < Sq:
        tail = Sq - Sq_full
        mask = (jnp.arange(Sk)[None, :] <=
                (jnp.arange(tail)[:, None] + q_offset + Sq_full))
        out = jnp.concatenate([out, _attend(q[:, Sq_full:], k, v, mask)],
                              axis=1)
    return out


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode: the pos-masked cache attention of
    chunk_attention at Sq=1.  q: (B,1,H,hd); pos: (B,) current lengths."""
    return chunk_attention(q, k_cache, v_cache, pos[:, None])


def chunk_attention(q, k_cache, v_cache, positions):
    """Causal attention of a prefill chunk at an arbitrary offset.

    q: (B,Sq,H,hd) chunk queries; caches: (B,S_max,Hkv,hd) already updated
    with this chunk's K/V; positions: (B,Sq) absolute query positions.
    Each query row attends every cache row at or before its own position —
    at offset 0 this reduces to plain causal prefill (rows past the chunk
    are masked to exact zeros), and at offset>0 it sees all earlier chunks."""
    Sk = k_cache.shape[1]
    mask = jnp.arange(Sk)[None, None, :] <= positions[:, :, None]  # (B,Sq,Sk)
    return _attend(q, k_cache, v_cache, mask[:, None])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg):
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.compute_dtype
    return {"wq": init_dense(ks[0], d, H * hd, dt),
            "wk": init_dense(ks[1], d, Hkv * hd, dt),
            "wv": init_dense(ks[2], d, Hkv * hd, dt),
            "wo": init_dense(ks[3], H * hd, d, dt)}


def gqa(p, x, cfg, positions, cache=None, cache_pos=None, paged=None):
    """cache: {"k","v"} (B, S_max, Hkv, hd), or (P, page_size, Hkv, hd)
    pools when a `PagedKV` bundle is passed, or None (train/prefill).

    Returns (out, new_cache)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = dense(x, p["wq"], cfg.quant).reshape(B, S, H, hd)
    k = dense(x, p["wk"], cfg.quant).reshape(B, S, Hkv, hd)
    v = dense(x, p["wv"], cfg.quant).reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = causal_attention(q, k, v)
        new_cache = None
    elif "ks" in cache:                          # int8 KV cache (quant_kv)
        # decode and prefill chunks both attend the stored int8 rows
        # (earlier chunks only exist quantized) via the same masked path
        new_cache = _update_cache_q(cache, k, v, cache_pos, paged, positions)
        if isinstance(paged, PagedKV) and paged.decode_kernel and S == 1:
            # page-bounded pallas decode: the pool is read as stored int8,
            # one (page_size, Hkv, hd) tile at a time (q row-quantized
            # exactly as decode_attention_q would)
            qq, qs = _quant_rows(q)
            out = pk.paged_decode_q(
                qq[:, 0], qs[:, 0], new_cache["k"], new_cache["ks"],
                new_cache["v"], new_cache["vs"], paged.tables,
                paged.n_pages, positions[:, 0] + 1, q.dtype)[:, None]
        else:
            view = new_cache if not isinstance(paged, PagedKV) else \
                {key: paged_view(new_cache[key], paged) for key in new_cache}
            out = decode_attention_q(q, view, positions)
    elif isinstance(paged, DenseKV):
        # speculative dense writes: per-position scatter with drop
        kc = dense_update(cache["k"], k, positions, paged)
        vc = dense_update(cache["v"], v, positions, paged)
        out = chunk_attention(q, kc, vc, positions)
        new_cache = {"k": kc, "v": vc}
    elif paged is not None:
        kc = paged_update(cache["k"], k, positions, paged)
        vc = paged_update(cache["v"], v, positions, paged)
        if paged.decode_kernel and S == 1:
            # page-bounded pallas decode kernel; the gather below stays
            # the parity oracle (and the Sq>1 prefill/verify path)
            out = pk.paged_decode(q[:, 0], kc, vc, paged.tables,
                                  paged.n_pages,
                                  positions[:, 0] + 1)[:, None]
        else:
            out = chunk_attention(q, paged_view(kc, paged),
                                  paged_view(vc, paged), positions)
        new_cache = {"k": kc, "v": vc}
    else:
        kc = _update_cache(cache["k"], k, cache_pos)
        vc = _update_cache(cache["v"], v, cache_pos)
        # decode (S=1, positions == cache_pos) and prefill chunks share
        # the same masked path over the cache
        out = chunk_attention(q, kc, vc, positions)
        new_cache = {"k": kc, "v": vc}
    return dense(out.reshape(B, S, H * hd), p["wo"], cfg.quant), new_cache


def _update_cache(cache, new, pos):
    """Insert `new` (B,S,…) at per-batch position `pos` (B,)."""
    B, S = new.shape[:2]
    if S == cache.shape[1]:
        return new.astype(cache.dtype)

    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache, new, pos)


def init_gqa_cache(cfg, batch, max_seq, dtype, num_pages=None):
    """num_pages=None: dense (batch, max_seq, …) reservations; otherwise a
    shared paged pool of (num_pages, page_size, …) — no batch axis, the
    engine's block tables carry the sequence↔page mapping."""
    hd = cfg.hd
    if num_pages is None:
        shape = (batch, max_seq, cfg.num_kv_heads, hd)
        sshape = (batch, max_seq, cfg.num_kv_heads)
    else:
        shape = (num_pages, cfg.page_size, cfg.num_kv_heads, hd)
        sshape = (num_pages, cfg.page_size, cfg.num_kv_heads)
    if getattr(cfg, "quant_kv", False):
        # int8 KV cache (beyond-paper: the paper's integer-MAC dataflow
        # applied to the cache, which dominates decode HBM bytes)
        return {"k": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "vs": jnp.ones(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# int8 KV cache (quant_kv) — BRAMAC integer arithmetic inside attention
# ---------------------------------------------------------------------------

def _quant_rows(x):
    """Per-(…, head) row int8 quantization over the feature dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _update_cache_q(cache, k, v, pos, paged=None, positions=None):
    kq, ks = _quant_rows(k)
    vq, vs = _quant_rows(v)
    if isinstance(paged, DenseKV):
        return {"k": dense_update(cache["k"], kq, positions, paged),
                "ks": dense_update(cache["ks"], ks, positions, paged),
                "v": dense_update(cache["v"], vq, positions, paged),
                "vs": dense_update(cache["vs"], vs, positions, paged)}
    if paged is not None:
        return {"k": paged_update(cache["k"], kq, positions, paged),
                "ks": paged_update(cache["ks"], ks, positions, paged),
                "v": paged_update(cache["v"], vq, positions, paged),
                "vs": paged_update(cache["vs"], vs, positions, paged)}
    return {"k": _update_cache(cache["k"], kq, pos),
            "ks": _update_cache(cache["ks"], ks, pos),
            "v": _update_cache(cache["v"], vq, pos),
            "vs": _update_cache(cache["vs"], vs, pos)}


def decode_attention_q(q, cache, positions):
    """Attention over the int8 cache — decode (Sq=1) and offset prefill
    chunks (Sq=C) alike; positions: (B, Sq) absolute query positions.

    Both dots run int8×int8→int32 on the MXU (the nd=1 endpoint of the
    BRAMAC digit loop): Q is row-quantized on the fly; K's scales factor
    out of the score dot; V's *per-position* scales fold into the
    probabilities elementwise before the PV dot, so V is consumed as
    stored int8 — no dequantized cache copy is ever materialized."""
    B, Sq, H, hd = q.shape
    kc, ks, vc, vs = cache["k"], cache["ks"], cache["v"], cache["vs"]
    Sk, Hkv = kc.shape[1], kc.shape[2]
    group = H // Hkv
    qq, qs = _quant_rows(q)                              # (B,Sq,H,hd),(B,Sq,H)
    qg = qq.reshape(B, Sq, Hkv, group, hd)
    scores_i = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,      # int8 MXU dot
                          preferred_element_type=jnp.int32)
    qs_g = qs.reshape(B, Sq, Hkv, group).transpose(0, 2, 3, 1)  # (B,Hkv,g,Sq)
    scores = scores_i.astype(jnp.float32) \
        * qs_g[..., None] * ks.transpose(0, 2, 1)[:, :, None, None, :]
    scores = scores / math.sqrt(hd)
    mask = (jnp.arange(Sk)[None, None, :]
            <= positions[:, :, None])[:, None, None]     # (B,1,1,Sq,Sk)
    probs = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
    # fold per-position V scales into the probabilities, requantize rows
    pv = probs * vs.transpose(0, 2, 1)[:, :, None, None, :]  # (B,Hkv,g,Sq,Sk)
    pq, pscale = _quant_rows(pv)
    out_i = jnp.einsum("bhgqk,bkhd->bqhgd", pq, vc,
                       preferred_element_type=jnp.int32)
    out = out_i.astype(jnp.float32) \
        * pscale.transpose(0, 3, 1, 2)[..., None]            # (B,Sq,Hkv,g,1)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3/DeepSeek style)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    dt = cfg.compute_dtype
    return {
        "w_dq": init_dense(ks[0], d, qr, dt),
        "q_norm": init_rmsnorm(qr, dt),
        "w_uq": init_dense(ks[1], qr, H * (nope + rope), dt),
        "w_dkv": init_dense(ks[2], d, kvr, dt),
        "kv_norm": init_rmsnorm(kvr, dt),
        "w_kr": init_dense(ks[3], d, rope, dt),
        "w_uk": init_dense(ks[4], kvr, H * nope, dt),
        "w_uv": init_dense(ks[5], kvr, H * vd, dt),
        "wo": init_dense(ks[6], H * vd, d, dt),
    }


def mla(p, x, cfg, positions, cache=None, cache_pos=None, paged=None):
    """Latent attention; the cache stores only (c_kv, k_rope) — the paper's
    BRAMAC quantization applies to every projection here as well."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = dense(rmsnorm(p["q_norm"], dense(x, p["w_dq"], cfg.quant),
                      cfg.norm_eps), p["w_uq"], cfg.quant)
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(p["kv_norm"], dense(x, p["w_dkv"], cfg.quant), cfg.norm_eps)
    k_rope = apply_rope(dense(x, p["w_kr"], cfg.quant)[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]   # (B,S,rope)

    if cache is not None and isinstance(paged, DenseKV):
        new_cache = {"c_kv": dense_update(cache["c_kv"], c_kv,
                                          positions, paged),
                     "k_rope": dense_update(cache["k_rope"], k_rope,
                                            positions, paged)}
        c_kv, k_rope = new_cache["c_kv"], new_cache["k_rope"]
        Sk = c_kv.shape[1]
    elif cache is not None and paged is not None:
        new_cache = {"c_kv": paged_update(cache["c_kv"], c_kv,
                                          positions, paged),
                     "k_rope": paged_update(cache["k_rope"], k_rope,
                                            positions, paged)}
        # up-projections run over the gathered view, exactly as the dense
        # path runs them over the full (B, max_seq, …) cache
        c_kv = paged_view(new_cache["c_kv"], paged)
        k_rope = paged_view(new_cache["k_rope"], paged)
        Sk = c_kv.shape[1]
    elif cache is not None:
        c_kv = _update_cache(cache["c_kv"], c_kv, cache_pos)
        k_rope = _update_cache(cache["k_rope"], k_rope, cache_pos)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        Sk = c_kv.shape[1]
    else:
        new_cache = None
        Sk = S

    k_nope = dense(c_kv, p["w_uk"], cfg.quant).reshape(B, Sk, H, nope)
    v = dense(c_kv, p["w_uv"], cfg.quant).reshape(B, Sk, H, vd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Sk, H, rope))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    if cache is not None:                        # decode or prefill chunk
        out = chunk_attention(q_full, k, v, positions)
    else:
        out = causal_attention(q_full, k[:, :S], v[:, :S])
    return dense(out.reshape(B, S, H * vd), p["wo"], cfg.quant), new_cache


def init_mla_cache(cfg, batch, max_seq, dtype, num_pages=None):
    lead = (batch, max_seq) if num_pages is None \
        else (num_pages, cfg.page_size)
    return {"c_kv": jnp.zeros(lead + (cfg.kv_lora_rank,), dtype),
            "k_rope": jnp.zeros(lead + (cfg.qk_rope_dim,), dtype)}


# ---------------------------------------------------------------------------
# Cross-attention (VLM: text queries attend to image patch embeddings)
# ---------------------------------------------------------------------------

def init_xattn(key, cfg):
    return init_gqa(key, cfg) | {
        "kv_norm": init_rmsnorm(cfg.d_model, cfg.compute_dtype)}


def xattn(p, x, cfg, vision_embeds, cache=None, cache_pos=None):
    """vision_embeds: (B, T_v, D) precomputed patch embeddings (stub
    frontend per the assignment).  K/V are position-free; for decode the
    projected K/V are cached once."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = dense(x, p["wq"], cfg.quant).reshape(B, S, H, hd)
    decoding = cache is not None and S == 1        # static condition
    if decoding:
        k, v = cache["k"], cache["v"]              # projected during prefill
    else:
        ve = rmsnorm(p["kv_norm"], vision_embeds, cfg.norm_eps)
        Tv = ve.shape[1]
        k = dense(ve, p["wk"], cfg.quant).reshape(B, Tv, Hkv, hd)
        v = dense(ve, p["wv"], cfg.quant).reshape(B, Tv, Hkv, hd)
    mask = jnp.ones((S, k.shape[1]), bool)
    out = _attend(q, k, v, mask)
    new_cache = {"k": k.astype(cache["k"].dtype),
                 "v": v.astype(cache["v"].dtype)} \
        if cache is not None else None
    return dense(out.reshape(B, S, H * hd), p["wo"], cfg.quant), new_cache


def init_xattn_cache(cfg, batch, dtype):
    shape = (batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
