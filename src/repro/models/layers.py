"""Shared neural layers: norms, dense (BRAMAC-aware), SwiGLU MLP, RoPE, embed.

Pure-functional: `init_*` returns a param pytree, `*_apply` consumes it.
Every matmul flows through `dense()` so the BRAMAC quantized path is a
single-switch feature across the whole model zoo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bramac_linear as bl


def dense(x: jax.Array, w: jax.Array, quant: bl.QuantConfig | None) -> jax.Array:
    """All model matmuls route here → BRAMAC integration point."""
    return bl.dense(x, w, quant)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def init_dense(key, d_in, d_out, dtype):
    return he_init(key, (d_in, d_out), dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": init_dense(k1, d_model, d_ff, dtype),
            "w_up": init_dense(k2, d_model, d_ff, dtype),
            "w_down": init_dense(k3, d_ff, d_model, dtype)}


def mlp(p, x, quant=None):
    g = dense(x, p["w_gate"], quant)
    u = dense(x, p["w_up"], quant)
    return dense(jax.nn.silu(g) * u, p["w_down"], quant)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab, d_model, dtype):
    k1, k2 = jax.random.split(key)
    return {"embedding": (jax.random.normal(k1, (vocab, d_model)) * 0.02
                          ).astype(dtype),
            "unembed": init_dense(k2, d_model, vocab, dtype)}


def embed(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p, x, quant=None):
    return dense(x, p["unembed"], quant)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
