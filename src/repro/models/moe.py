"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch, EP.

Tokens are scattered into a per-expert capacity buffer (E, C, d) — the
Switch-Transformer dispatch — so expert compute is E·C·(3·d·ff) ≈ the
*active* FLOPs (k/E of dense-all-experts), which keeps the roofline's
MODEL_FLOPS/HLO_FLOPs ratio honest.  Experts are sharded over the `model`
mesh axis (expert parallelism); the scatter/gather lowers to all-to-all-
style collectives under pjit.

Router math is f32; a Switch-style load-balancing aux loss is returned.
Tokens overflowing an expert's capacity are dropped (standard; tests use a
no-drop capacity to check exactness against the dense reference).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bramac_linear as bl
from repro.core.quant import QuantizedTensor
from repro.models.layers import he_init
from repro.parallel import ep, sharding


def _expert_matmul(x, w):
    """(E,C,a)·(E,a,b)→(E,C,b); takes float or serving-quantized weights.

    Quantized weights route through the expert-parallel shard_map einsum
    whenever a sharding ctx is active and its `expert` axis divides E —
    bit-exact vs the single-device path, so activation is a pure placement
    decision.  Float (training) weights keep the plain einsum: pjit +
    `constrain` already shard it without an explicit collective."""
    if isinstance(w, QuantizedTensor):
        ctx = sharding.active()
        if ctx is not None and ep.shardable(x, ctx):
            return ep.ep_quant_einsum_edf(x, w, mesh=ctx.mesh)
        return bl.serve_einsum_edf(x, w, transpose_out=False)
    return jnp.einsum("ecd,edf->ecf", x, w)


def init_moe(key, cfg):
    d, ff, E = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    dt = cfg.compute_dtype
    return {
        "router": he_init(ks[0], (d, E), jnp.float32),
        "w_gate": he_init(ks[1], (E, d, ff), dt, fan_in=d),
        "w_up": he_init(ks[2], (E, d, ff), dt, fan_in=d),
        "w_down": he_init(ks[3], (E, ff, d), dt, fan_in=ff),
    }


def moe_capacity(T: int, E: int, k: int, capacity_factor: float) -> int:
    """Per-expert buffer rows C — the ONE formula every dispatch path
    (dense, EP-global, EP-per-source and its reference) derives from, so
    their drop semantics can only diverge by documented capacity math
    (per-source uses C_src = ceil(C / ep_size) of this same C)."""
    return int(max(1, round(T * k / E * capacity_factor)))


def moe(p, x, cfg, capacity_factor: float | None = None,
        dispatch: str | None = None):
    """x: (B, S, d) → (out, aux_loss).

    `capacity_factor` / `dispatch` default to `cfg.moe_capacity_factor` /
    `cfg.ep_dispatch` (the knobs Engine and launch/serve.py plumb down).
    dispatch="per_source" hands the WHOLE layer to `ep.ep_moe`'s lossy
    GShard-style path when a sharding ctx is active and can token+expert-
    shard it — forwarding the caller's `capacity_factor`, never ep_moe's
    default (a silent mismatch between the sharded and dense paths,
    regression-tested in tests/test_parallel_ep.py).  Without a ctx (or a
    non-dividing mesh) it falls through to the dense path below, which is
    exactly per-source semantics at ep_size=1 (C_src = C).
    """
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    if dispatch is None:
        dispatch = cfg.ep_dispatch
    if dispatch not in ("global", "per_source"):
        raise ValueError(f"ep_dispatch must be 'global' or 'per_source', "
                         f"got {dispatch!r}")
    if dispatch == "per_source":
        ctx = sharding.active()
        if ctx is not None and ep.layer_shardable(x, cfg, ctx):
            return ep.ep_moe(p, x, cfg, mesh=ctx.mesh,
                             capacity_factor=capacity_factor,
                             dispatch="per_source")

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]           # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (T,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- capacity dispatch ----
    C = moe_capacity(T, E, k, capacity_factor)
    a = top_i.reshape(T * k)                                # assignments
    if cfg.moe_dispatch == "sort":
        pos = _rank_in_expert_sort(a, E)
    else:
        pos = _rank_in_expert_cumsum(a, E)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1)

    xk = jnp.repeat(xf, k, axis=0)                          # (T*k, d)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[a, pos_c].add(jnp.where(keep[:, None], xk, 0))

    # ---- expert compute (EP: E sharded over `model`) ----
    g = _expert_matmul(buf, p["w_gate"])
    u = _expert_matmul(buf, p["w_up"])
    ye = _expert_matmul(jax.nn.silu(g) * u, p["w_down"])

    # ---- combine ----
    yk = ye[a, pos_c]                                       # (T*k, d)
    w = (top_p.reshape(T * k).astype(x.dtype)
         * keep.astype(x.dtype))[:, None]
    out = jnp.sum((yk * w).reshape(T, k, d), axis=1).reshape(B, S, d)

    # ---- Switch load-balance loss ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _rank_in_expert_cumsum(a: jax.Array, E: int) -> jax.Array:
    """The original one-hot running-count rank (moe_dispatch="cumsum") —
    O(T·k, E) memory; kept as the §Perf baseline and property-tested
    against the sort path in tests/test_moe_routing_properties.py."""
    onehot = jax.nn.one_hot(a, E, dtype=jnp.int32)          # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot          # rank in expert
    return jnp.take_along_axis(pos_in_e, a[:, None], axis=1)[:, 0]


def _rank_in_expert_sort(a: jax.Array, E: int) -> jax.Array:
    """pos[i] = #{j : a[j] == a[i], j before i in expert order}.

    argsort-based: stable-sort assignments, rank within the sorted run of
    each expert (index − expert start offset), scatter ranks back.
    O(n log n) time, O(n) memory — replaces the (T·k, E) one-hot cumsum
    whose reduce-window lowering is quadratic at 32k-token scale (§Perf).
    """
    n = a.shape[0]
    order = jnp.argsort(a, stable=True)                     # expert-major
    sorted_a = a[order]
    counts = jnp.bincount(a, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_a]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    return pos


def moe_reference(p, x, cfg):
    """Dense all-experts reference (exact, no drops) for tests."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    combine = jnp.einsum("bske,bsk->bse",
                         jax.nn.one_hot(top_i, E, dtype=jnp.float32),
                         top_p).astype(x.dtype)
    g = jnp.einsum("bsd,edf->ebsf", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->ebsf", x, p["w_up"])
    ye = jnp.einsum("ebsf,efd->ebsd", jax.nn.silu(g) * u, p["w_down"])
    return jnp.einsum("ebsd,bse->bsd", ye, combine)
