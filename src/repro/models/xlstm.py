"""xLSTM mixers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, inherently sequential) — arXiv:2405.04517.

mLSTM cell (per head, stabilized exponential gating):
    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) k_t v_tᵀ
    n_t = (same recurrence on k_t)
    h_t = (q_t C_t) / max(|q_t·n_t|, exp(-m_t))

Training uses the chunkwise-parallel form (intra-chunk quadratic attention
+ inter-chunk (C, n, m) carry) — O(S·T) memory, compact HLO, MXU-friendly;
`mlstm_recurrent_ref` is the step-by-step oracle used by tests.  Decode is
the O(1)-state recurrent step (the reason xlstm runs the long_500k shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, init_rmsnorm, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d = cfg.d_model
    dp = int(cfg.mlstm_proj_factor * d)
    ks = jax.random.split(key, 8)
    dt = cfg.compute_dtype
    return {
        "w_up": init_dense(ks[0], d, 2 * dp, dt),
        "wq": init_dense(ks[1], dp, dp, dt),
        "wk": init_dense(ks[2], dp, dp, dt),
        "wv": init_dense(ks[3], dp, dp, dt),
        "w_if": init_dense(ks[4], dp, 2 * cfg.num_heads, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((cfg.num_heads,)),
                                 jnp.full((cfg.num_heads,), 3.0)]),
        "norm": init_rmsnorm(dp, dt),
        "w_down": init_dense(ks[5], dp, d, dt),
    }


def _mlstm_gates(p, xm, H):
    gf = xm.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    logi, logf = gf[..., :H], jax.nn.log_sigmoid(gf[..., H:])
    return logi, logf                       # (..., H)


def _qkv(p, xm, cfg, H):
    B, T, dp = xm.shape
    dh = dp // H
    q = dense(xm, p["wq"], cfg.quant).reshape(B, T, H, dh)
    k = dense(xm, p["wk"], cfg.quant).reshape(B, T, H, dh) / (dh ** 0.5)
    v = dense(xm, p["wv"], cfg.quant).reshape(B, T, H, dh)
    return q, k, v


def _mlstm_chunk(carry, inputs):
    """One chunk of the chunkwise-parallel mLSTM (all heads batched).

    carry: C (B,H,dk,dv), n (B,H,dk), m (B,H)
    inputs: q,k,v (B,T,H,dh), logi/logf (B,T,H)
    """
    C, n, m = carry
    q, k, v, logi, logf = inputs
    B, T, H, dh = q.shape
    lam = jnp.cumsum(logf, axis=1)                        # Λ_t inclusive (B,T,H)
    lam_T = lam[:, -1]                                    # (B,H)

    # per-token output stabilizer: max(Λ_t+m, max_{s≤t}(Λ_t−Λ_s+logi_s))
    a = logi - lam                                        # logi_s − Λ_s
    intra_max = jax.lax.cummax(a, axis=1)
    m_out = jnp.maximum(lam + m[:, None], lam + intra_max)  # (B,T,H)

    # intra-chunk quadratic term: w[t,s] = exp(Λ_t−Λ_s+logi_s−m_out_t), s≤t
    scores = jnp.einsum("bthd,bshd->bhts", q, k)          # (B,H,T,T)
    lam_h = lam.transpose(0, 2, 1)                        # (B,H,T)
    logw = (lam_h[:, :, :, None] - lam_h[:, :, None, :]
            + logi.transpose(0, 2, 1)[:, :, None, :])     # (B,H,T,S)
    m_out_h = m_out.transpose(0, 2, 1)                    # (B,H,T)
    tri = jnp.tril(jnp.ones((T, T), bool))
    w = jnp.where(tri, jnp.exp(logw - m_out_h[..., None]), 0.0)
    ws = w * scores
    h_intra = jnp.einsum("bhts,bshd->bthd", ws, v)
    n_intra = jnp.sum(ws, axis=-1).transpose(0, 2, 1)     # (B,T,H)

    # contribution from carried state
    w_prev = jnp.exp(lam + m[:, None] - m_out)            # (B,T,H)
    h_prev = jnp.einsum("bthd,bhde->bthe", q, C) * w_prev[..., None]
    n_prev = jnp.einsum("bthd,bhd->bth", q, n) * w_prev

    denom = jnp.maximum(jnp.abs(n_intra + n_prev), jnp.exp(-m_out))
    h = (h_intra + h_prev) / denom[..., None]

    # state update (fold the whole chunk into (C, n, m))
    m_new = jnp.maximum(lam_T + m, lam_T + jnp.max(a, axis=1))
    decay = jnp.exp(lam_T + m - m_new)                    # (B,H)
    wk = jnp.exp(lam_T[:, None] - lam + logi - m_new[:, None])  # (B,T,H)
    C_new = decay[..., None, None] * C + jnp.einsum(
        "bthd,bthe->bhde", k * wk[..., None], v)
    n_new = decay[..., None] * n + jnp.einsum("bth,bthd->bhd", wk, k)
    return (C_new, n_new, m_new), h


def mlstm_sequence(p, x, cfg, state=None):
    """x: (B,S,d) → (out, state). state: (C, n, m) per head."""
    B, S, d = x.shape
    H = cfg.num_heads
    dp = int(cfg.mlstm_proj_factor * d)
    dh = dp // H
    up = dense(x, p["w_up"], cfg.quant)
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v = _qkv(p, xm, cfg, H)
    logi, logf = _mlstm_gates(p, xm, H)

    if state is None:
        state = init_mlstm_state(cfg, B)
    T = min(cfg.chunk_size, S)
    if S % T:
        T = S
    nc = S // T

    def split(a):
        return a.reshape(B, nc, T, *a.shape[2:]).swapaxes(0, 1)

    carry, hs = jax.lax.scan(
        _mlstm_chunk, state,
        tuple(split(a) for a in (q, k, v, logi, logf)))
    h = hs.swapaxes(0, 1).reshape(B, S, H * dh).astype(x.dtype)
    out = rmsnorm(p["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return dense(out, p["w_down"], cfg.quant), carry


def mlstm_step(p, x, cfg, state):
    """Single-token decode; O(1) state update."""
    (C, n, m) = state
    B = x.shape[0]
    H = cfg.num_heads
    dp = int(cfg.mlstm_proj_factor * cfg.d_model)
    dh = dp // H
    up = dense(x[:, 0], p["w_up"], cfg.quant)
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v = _qkv(p, xm[:, None], cfg, H)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                   # (B,H,dh)
    logi, logf = _mlstm_gates(p, xm[:, None], H)
    logi, logf = logi[:, 0], logf[:, 0]                   # (B,H)

    m_new = jnp.maximum(logf + m, logi)
    f_s = jnp.exp(logf + m - m_new)
    i_s = jnp.exp(logi - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = f_s[..., None] * n + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, H * dh).astype(x.dtype)
    out = rmsnorm(p["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return dense(out, p["w_down"], cfg.quant)[:, None], (C, n, m_new)


def init_mlstm_state(cfg, batch):
    H = cfg.num_heads
    dp = int(cfg.mlstm_proj_factor * cfg.d_model)
    dh = dp // H
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_recurrent_ref(p, x, cfg):
    """Step-by-step oracle (tests only)."""
    B, S, _ = x.shape
    state = init_mlstm_state(cfg, B)
    outs = []
    for t in range(S):
        o, state = mlstm_step(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    dp = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 7)
    dt = cfg.compute_dtype
    return {
        "w_gates": init_dense(ks[0], d, 4 * d, dt),       # z, i, f, o
        "r_gates": init_dense(ks[1], d, 4 * d, dt),       # recurrent
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "norm": init_rmsnorm(d, dt),
        "w_up": init_dense(ks[2], d, 2 * dp, dt),
        "w_down": init_dense(ks[3], dp, d, dt),
    }


def _slstm_cell(p, wx_t, hcnm, cfg):
    """wx_t: the input projection W·x_t, precomputed outside the scan (the
    big matmul is hoisted and batched over the sequence — MXU-friendly;
    only the recurrent R·h_{t-1} stays sequential)."""
    h, c, n, m = hcnm                                     # (B,d) f32 each
    g = (wx_t.astype(jnp.float32)
         + h.astype(jnp.float32) @ p["r_gates"].astype(jnp.float32)
         + p["b_gates"])
    z, gi, gf, go = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(z)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + m, gi)
    i_s = jnp.exp(gi - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = jax.nn.sigmoid(go) * (c / jnp.maximum(n, 1e-6))
    return (h_new, c, n, m_new)


def slstm_sequence(p, x, cfg, state=None):
    B, S, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, B)
    wx = dense(x, p["w_gates"], cfg.quant)        # hoisted input projection

    def step(carry, wx_t):
        new = _slstm_cell(p, wx_t, carry, cfg)
        return new, new[0]

    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    h = rmsnorm(p["norm"], h, cfg.norm_eps)
    up = dense(h, p["w_up"], cfg.quant)
    a, b = jnp.split(up, 2, axis=-1)
    return dense(jax.nn.gelu(a) * b, p["w_down"], cfg.quant), state


def slstm_step(p, x, cfg, state):
    wx = dense(x[:, 0], p["w_gates"], cfg.quant)
    state = _slstm_cell(p, wx, state, cfg)
    h = rmsnorm(p["norm"], state[0][:, None].astype(x.dtype), cfg.norm_eps)
    up = dense(h, p["w_up"], cfg.quant)
    a, b = jnp.split(up, 2, axis=-1)
    return dense(jax.nn.gelu(a) * b, p["w_down"], cfg.quant), state


def init_slstm_state(cfg, batch):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, jnp.full((batch, d), -1e30, jnp.float32))
