"""Mamba (selective SSM) mixer — chunked parallel scan for training,
O(1)-state recurrent step for decode (jamba's sub-quadratic half).

    x → in_proj → (x, z);  x → causal depthwise conv → SiLU
    Δ, B, C selected from x;  h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t
    y_t = C_t · h_t + D x_t;  out = (y ⊙ SiLU(z)) → out_proj

Training runs `lax.scan` over sequence chunks with a `lax.associative_scan`
inside each chunk: memory is O(chunk · d_inner · N) instead of
O(S · d_inner · N), and the lowered HLO stays compact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense


def _dt_rank(cfg):
    return cfg.mamba_dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg):
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    N, K, R = cfg.mamba_d_state, cfg.mamba_d_conv, _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    dt = cfg.compute_dtype
    # S4D-real initialization for A
    a_log = jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32),
                                     (d_in, N)))
    return {
        "w_in": init_dense(ks[0], d, 2 * d_in, dt),
        "conv_w": (jax.random.normal(ks[1], (K, d_in)) / K).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "w_bc": init_dense(ks[2], d_in, 2 * N, dt),
        "w_dt_down": init_dense(ks[3], d_in, R, dt),
        "w_dt_up": init_dense(ks[4], R, d_in, dt),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus ≈ 1e-2
        "a_log": a_log,
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": init_dense(ks[5], d_in, d, dt),
    }


def _ssm_inputs(p, xc, cfg):
    """Common path after conv: returns (dA, dBx, C, y_skip) in f32."""
    N = cfg.mamba_d_state
    bc = xc @ p["w_bc"]
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)   # (..., N)
    dt = jax.nn.softplus(
        (xc @ p["w_dt_down"] @ p["w_dt_up"]).astype(jnp.float32)
        + p["dt_bias"])                                      # (..., d_in)
    A = -jnp.exp(p["a_log"])                                 # (d_in, N)
    dA = jnp.exp(dt[..., None] * A)                          # (..., d_in, N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bm[..., None, :]
    return dA, dBx, Cm


def _chunk_scan(carry, chunk, p, cfg):
    """One chunk: associative scan over time inside, carry h across chunks."""
    h0 = carry                                  # (B, d_in, N) f32
    xc = chunk                                  # (B, T, d_in)
    dA, dBx, Cm = _ssm_inputs(p, xc, cfg)       # (B,T,d_in,N) ×2, (B,T,N)

    def combine(a, b):
        (A1, b1), (A2, b2) = a, b
        return A1 * A2, A2 * b1 + b2

    # prepend the carry as an initial element via the b-term of step 0
    dBx0 = dBx.at[:, 0].add(dA[:, 0] * h0)
    As, hs = jax.lax.associative_scan(combine, (dA, dBx0), axis=1)
    y = jnp.einsum("btdn,btn->btd", hs, Cm)
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    return hs[:, -1], y


def mamba_sequence(p, x, cfg, h0=None, conv0=None):
    """x: (B, S, d) → (out (B,S,d), (h_final, conv_tail)).

    The (h, conv_tail) pair is the recurrent state — this is what makes
    long_500k decoding O(1) per token for the SSM archs.
    """
    B, S, d = x.shape
    d_in = cfg.mamba_expand * d
    K = cfg.mamba_d_conv
    xz = dense(x, p["w_in"], cfg.quant)
    xr, z = jnp.split(xz, 2, axis=-1)            # (B,S,d_in)

    # causal depthwise conv along S (with optional tail state from decode)
    if conv0 is None:
        conv0 = jnp.zeros((B, K - 1, d_in), xr.dtype)
    xpad = jnp.concatenate([conv0, xr], axis=1)
    xc = sum(xpad[:, i:i + S] * p["conv_w"][i] for i in range(K))
    xc = jax.nn.silu(xc + p["conv_b"])
    conv_tail = xpad[:, -(K - 1):] if K > 1 else conv0

    if h0 is None:
        h0 = jnp.zeros((B, d_in, cfg.mamba_d_state), jnp.float32)

    T = min(cfg.chunk_size, S)
    if S % T:
        T = S                                     # fall back to one chunk
    nc = S // T
    xcc = xc.reshape(B, nc, T, d_in).swapaxes(0, 1)
    hT, ys = jax.lax.scan(
        lambda c, ch: _chunk_scan(c, ch, p, cfg), h0, xcc)
    y = ys.swapaxes(0, 1).reshape(B, S, d_in)
    out = dense(y.astype(x.dtype) * jax.nn.silu(z), p["w_out"], cfg.quant)
    return out, (hT, conv_tail)


def mamba_step(p, x, cfg, state):
    """Single-token decode. x: (B, 1, d); state: (h, conv_tail)."""
    h, conv_tail = state
    B = x.shape[0]
    d_in = cfg.mamba_expand * cfg.d_model
    xz = dense(x[:, 0], p["w_in"], cfg.quant)
    xr, z = jnp.split(xz, 2, axis=-1)            # (B, d_in)
    window = jnp.concatenate([conv_tail, xr[:, None]], axis=1)  # (B,K,d_in)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])
    dA, dBx, Cm = _ssm_inputs(p, xc, cfg)        # (B,d_in,N), (B,N)
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm) + p["d_skip"] * xc.astype(jnp.float32)
    out = dense(y.astype(x.dtype) * jax.nn.silu(z), p["w_out"], cfg.quant)
    return out[:, None], (h, window[:, 1:])


def init_mamba_state(cfg, batch, dtype):
    d_in = cfg.mamba_expand * cfg.d_model
    return (jnp.zeros((batch, d_in, cfg.mamba_d_state), jnp.float32),
            jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype))
