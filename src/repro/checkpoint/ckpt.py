"""Checkpointing: atomic, async-capable, mesh-agnostic (elastic restore).

Layout:  <dir>/step_<N>/
            manifest.json     {path: {dtype, shape}}, treedef repr
            arrays.npz        flat key → ndarray

Arrays are saved by *path string*, not by position, so checkpoints survive
refactors that reorder dicts.  `restore(..., shardings=...)` places leaves
onto any mesh — resharding to a different topology (elastic scale-up/down)
is just a different `shardings` pytree.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import numpy as np

import jax
import ml_dtypes

SEP = "/"

# importing ml_dtypes registers the extended dtypes with numpy — _decode's
# np.dtype("bfloat16") lookups depend on it, so verify at import time
assert np.dtype("bfloat16") == np.dtype(ml_dtypes.bfloat16)

_NATIVE_KINDS = set("biufc")


def _encode(v: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bfloat16, …) — round-trip via raw bytes."""
    if v.dtype.kind in _NATIVE_KINDS:
        return v
    return np.frombuffer(v.tobytes(), np.uint8).reshape(
        v.shape + (v.dtype.itemsize,))


def _decode(raw: np.ndarray, dtype_str: str, shape) -> np.ndarray:
    dtype = np.dtype(dtype_str)
    if dtype.kind in _NATIVE_KINDS:
        return raw
    return np.frombuffer(raw.tobytes(), dtype).reshape(shape)


def _flatten(tree) -> dict:
    flat = {}

    def visit(path, leaf):
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _unflatten_into(skeleton, flat: dict):
    def visit(path, leaf):
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        return flat[key]

    return jax.tree_util.tree_map_with_path(visit, skeleton)


def save(directory: str, step: int, tree, *, blocking: bool = True):
    """Atomic save of a pytree; pass blocking=False for async (snapshot is
    taken synchronously via device_get, the file write happens in a
    thread — the standard async-checkpoint split)."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace(SEP, "|"): _encode(v) for k, v in flat.items()})
        manifest = {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                    for k, v in flat.items()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, skeleton, *, shardings=None):
    """Restore into `skeleton`'s structure.  `shardings` (optional pytree of
    NamedSharding) reshards every leaf — elastic restore onto a new mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k.replace("|", SEP):
                _decode(z[k], manifest[k.replace("|", SEP)]["dtype"],
                        manifest[k.replace("|", SEP)]["shape"])
                for k in z.files}
    tree = _unflatten_into(skeleton, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda leaf, s: jax.device_put(leaf, s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
    # restore original dtypes (npz keeps them, but guard vs skeleton)
    return jax.tree_util.tree_map(
        lambda leaf, ref: leaf.astype(ref.dtype)
        if hasattr(ref, "dtype") and leaf.dtype != ref.dtype else leaf,
        tree, skeleton)


def prune(directory: str, keep: int = 3):
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
