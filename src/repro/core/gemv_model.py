"""Analytical GEMV cycle model — BRAMAC-1DA/2SA vs CCB / CoMeFa (Fig 11).

The paper benchmarks GEMV `y[R] = W[R,C] @ x[C]` on a SINGLE BRAM block
("performance normalized to BRAM utilization"), counting cycles with a
deterministic analytical model, persistent (weights resident) and
non-persistent (weight loading included).

**BRAMAC mapping** (Fig 2): dummy-array lanes hold a tile of `R_tile`
outputs; each MAC2 consumes one column *pair*, so a dot product takes
`ceil(C/2)` MAC2 issues of `mac2_latency` cycles each.  The accumulator row
must be drained (readout_busy cycles) every `max_dot_product_macs` MACs and
once at the end of each tile's dot product.

**CCB/CoMeFa mapping** (derived from the paper's §VI-C discussion): the dot
product is parallelized *across* the 160 lanes (transposed layout — element
c of x and column c of W live in lane c%160), one output at a time:
`n_seg = ceil(C/160)` sequential bit-serial MACs per output, then an
in-memory reduction across lanes folds the per-lane partial sums.  A packing
factor k keeps k segments' results resident so only `ceil(n_seg/k)`
reductions are needed — exactly the paper's "column size 480 → 3 sequential
MACs before a slow in-memory reduction / column size 128 → a reduction after
every MAC".  Per-MAC latencies are Table II's 16/42/113 (unsigned — the
paper notes CCB/CoMeFa would be slower still for 2's complement).  The
reduction cost is a log-tree of bit-serial adds; the paper does not tabulate
it, so we use T_red(p) = 6p + 8 cycles (DERIVED, calibrated to the paper's
"up to 3.3×/2.8×/2.4×" persistent speedups; see EXPERIMENTS.md §Fig11).

**Non-persistent**: CCB/CoMeFa cannot overlap loading with compute (their
CIM instructions occupy the write port → "this prevents tiling"), so load
cycles add serially: `R*C*p/40` port-write cycles (+ transposition handled
by the swizzle hardware at line rate).  BRAMAC overlaps loading with compute
via the eFSM; only the main-BRAM busy cycles (weight-read issues + accumulator
readouts) and any load remainder are exposed:
`max(compute, load + busy)`.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.arch_models import CCB, COMEFA_D, BitSerialBram
from repro.core.efsm import BRAMAC_1DA, PORT_BITS, Variant

T_RED_COEF = (6, 8)     # T_red(p) = 6p + 8 (calibrated, see module docstring)


def reduction_cycles(bits: int) -> int:
    a, b = T_RED_COEF
    return a * bits + b


# ---------------------------------------------------------------------------
# BRAMAC
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemvCycles:
    compute: int          # cycles the CIM engine is computing
    load: int             # weight-loading port cycles (non-persistent only)
    port_busy: int        # main-BRAM busy cycles (reads for copy + readouts)
    total_persistent: int
    total_nonpersistent: int


def bramac_gemv(variant: Variant, R: int, C: int, bits: int,
                signed: bool = True) -> GemvCycles:
    lanes = variant.mac2_lanes(bits)          # output rows per tile
    tiles = math.ceil(R / lanes)
    n_mac2 = math.ceil(C / 2)
    lat = variant.mac2_latency(bits, signed)
    # accumulator drains: every max_dot MACs and once at end of dot product
    max_dot = variant.max_dot_product_macs(bits)
    drains = math.ceil(C / max_dot)
    readout = variant.readout_busy_cycles()
    per_tile_compute = n_mac2 * lat + drains * readout
    compute = tiles * per_tile_compute + 2    # +2: initial un-pipelined copy
    # port busy: weight-read issues + readouts (these block tile loading)
    busy = tiles * (n_mac2 * variant.port_busy_per_mac2 + drains * readout)
    load = math.ceil(R * C * bits / PORT_BITS)
    nonpersistent = max(compute, load + busy)
    return GemvCycles(compute, load, busy, compute, nonpersistent)


# ---------------------------------------------------------------------------
# CCB / CoMeFa
# ---------------------------------------------------------------------------

def bitserial_gemv(arch: BitSerialBram, R: int, C: int, bits: int,
                   pack: int = 1, streams_input: bool = False) -> GemvCycles:
    """pack: CCB packing factor (1/2/4); CoMeFa streams the input operand
    (streams_input=True) instead of writing input copies."""
    n_seg = math.ceil(C / arch.lanes)
    k_eff = min(pack, n_seg) if pack > 1 else 1
    per_out = n_seg * arch.mac_cycles(bits) \
        + math.ceil(n_seg / k_eff) * reduction_cycles(bits)
    compute = R * per_out
    input_writes = 0 if streams_input else bits * n_seg
    compute += input_writes
    load = math.ceil(R * C * bits / PORT_BITS)
    # CIM occupies the ports: loading cannot overlap compute (no tiling)
    return GemvCycles(compute, load, compute, compute, compute + load)


# ---------------------------------------------------------------------------
# Fig 11 speedup heatmaps
# ---------------------------------------------------------------------------

ROW_SIZES = (64, 96, 128, 160, 256, 320, 512)       # matrix rows R
COL_SIZES = (128, 160, 256, 320, 480)               # matrix cols C

COMPETITORS = {
    "CCB-Pack-4": lambda R, C, b: bitserial_gemv(CCB, R, C, b, pack=4),
    "CCB-Pack-2": lambda R, C, b: bitserial_gemv(CCB, R, C, b, pack=2),
    "CoMeFa": lambda R, C, b: bitserial_gemv(COMEFA_D, R, C, b,
                                             streams_input=True),
}


def speedup_grid(bits: int, persistent: bool, variant: Variant = BRAMAC_1DA,
                 competitor: str = "CCB-Pack-4"):
    """Fig 11: speedup of BRAMAC (cycles) over a competitor, per (R, C)."""
    comp = COMPETITORS[competitor]
    grid = {}
    for R in ROW_SIZES:
        for C in COL_SIZES:
            ours = bramac_gemv(variant, R, C, bits)
            theirs = comp(R, C, bits)
            key = "total_persistent" if persistent else "total_nonpersistent"
            grid[(R, C)] = getattr(theirs, key) / getattr(ours, key)
    return grid


def max_speedups(variant: Variant = BRAMAC_1DA) -> dict:
    """Headline 'up to' numbers (paper: 3.3/2.8/2.4 persistent,
    4.1/3.4/2.8 non-persistent for 2/4/8-bit, vs the slower of CCB/CoMeFa)."""
    out = {}
    for persistent in (True, False):
        for bits in (2, 4, 8):
            best = max(
                max(speedup_grid(bits, persistent, variant, c).values())
                for c in COMPETITORS)
            tag = "persistent" if persistent else "nonpersistent"
            out[(tag, bits)] = best
    return out
