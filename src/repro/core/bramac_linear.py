"""BRAMAC quantized linear — the paper's technique as a composable module.

Two execution styles, matching the paper's two deployment modes:

  * **training / QAT** (`mode="qat"`): fake-quant forward through the BRAMAC
    integer dataflow with straight-through gradients (`ops.bramac_dense`).
  * **serving** (`mode="serve"`): weights are quantized **once** offline
    (`prepare_serving`) into int8/packed storage — the "main BRAM" resident
    layout — and every call quantizes activations on the fly and runs the
    integer kernel.  This is the persistent/tiling inference of §VI.

`QuantConfig.bits ∈ {2,4,8}` selects the MAC precision exactly as BRAMAC's
`prec` instruction field does.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """BRAMAC precision config (the CIM instruction's static fields)."""
    enabled: bool = False
    bits_w: int = 8          # weight precision (2/4/8)
    bits_a: int = 8          # activation precision (2/4/8)
    use_kernel: bool = False  # route through the Pallas kernel (slow on CPU
    #                           interpret; ref dataflow otherwise — identical
    #                           integer semantics, tested in test_kernels.py)

    def __post_init__(self):
        if self.bits_w not in quant.SUPPORTED_BITS or \
           self.bits_a not in quant.SUPPORTED_BITS:
            raise ValueError("BRAMAC supports 2/4/8-bit only")


FP32 = QuantConfig(enabled=False)


def dense(x: jax.Array, w, cfg: QuantConfig | None) -> jax.Array:
    """Linear y = x @ w through the configured path.

    w may be a float array (fp or QAT fake-quant path) or a pre-quantized
    `QuantizedTensor` (serving path: int weights resident in HBM — the
    persistent-weights deployment of §VI)."""
    if isinstance(w, quant.QuantizedTensor):
        return serve_dense(x, w, cfg)
    if cfg is None or not cfg.enabled:
        return x @ w
    return ops.bramac_dense(x, w, cfg.bits_w, cfg.bits_a, cfg.use_kernel)


# ---------------------------------------------------------------------------
# Serving path: offline weight quantization ("persistent weights in BRAM")
# ---------------------------------------------------------------------------

def prepare_serving(w: jax.Array, cfg: QuantConfig) -> quant.QuantizedTensor:
    """Quantize a weight once for inference.

    All matmul weights here are (..., in, out): per-output-channel scales
    over the contraction axis (−2); 4/2-bit values are bit-packed along the
    contraction axis — the dense main-BRAM storage layout that gives the
    paper its 100% utilization (Fig 10), and handles stacked layer/expert
    weights of any rank."""
    # axis −2 end-relative: stacked (periods, …, in, out) weights get
    # scan-sliced at trace time, so static axes must count from the end.
    return quant.quantize(w, cfg.bits_w, axis=w.ndim - 2,
                          pack=cfg.bits_w < 8, pack_axis=-2)


def serve_dense(x: jax.Array, qw: quant.QuantizedTensor,
                cfg: QuantConfig | None) -> jax.Array:
    """Inference-time linear with pre-quantized HBM-resident weights.

    TPU adaptation note (DESIGN.md §7): the MXU executes one int8×int8
    pass natively, which is the nd=1 endpoint of the BRAMAC digit loop for
    ≤8-bit operands; the bit-serial structure survives as the *storage*
    format (packed int4/int2) and in the validated Pallas kernel."""
    bits_a = cfg.bits_a if (cfg and cfg.enabled) else min(qw.bits, 8)
    use_kernel = bool(cfg.use_kernel) if cfg else False
    x2 = x.reshape(-1, x.shape[-1])
    qx = quant.quantize(x2, bits_a, axis=-1)
    w_vals = qw.unpacked_values()
    y = ops.quant_matmul(qx.values, w_vals, qx.scale, qw.scale,
                         bits_a=bits_a, bits_w=qw.bits,
                         out_dtype=x.dtype, use_kernel=use_kernel)
    return y.reshape(*x.shape[:-1], y.shape[-1])


def edf_accumulate(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """Unit-scale int32 mode of the expert einsum "ecd,edf->ecf": batched
    int8 dot_general with an int32 accumulator and NO dequant — the
    per-tile accumulator of BRAMAC §VI many-tile scale-out, and the
    expert-einsum analogue of `ops.quant_matmul(..., out_dtype=jnp.int32)`
    with unit scales.  `parallel/ep.py` runs this per shard so partials
    meet in an integer `psum` before the one dequant epilogue —
    blocking/sharding cannot perturb the result."""
    return jax.lax.dot_general(
        x_q, w_q,
        (((2,), (1,)), ((0,), (0,))),                       # batch E
        preferred_element_type=jnp.int32)                   # (E, C, f)


def serve_einsum_edf(x: jax.Array, qw: quant.QuantizedTensor,
                     transpose_out: bool, bits_a: int = 8) -> jax.Array:
    """Quantized expert einsum: "ecd,edf->ecf" (transpose_out=False) or
    "ecf,efd->ecd" (True, same contraction layout).  Quantize-activations +
    `edf_accumulate` + dequant epilogue — expert parallelism preserved."""
    qx = quant.quantize(x, bits_a, axis=-1)                 # per (e,c) row
    acc = edf_accumulate(qx.values, qw.unpacked_values())
    return (acc.astype(jnp.float32) * qx.scale * qw.scale   # (E,1,f) bcast
            ).astype(x.dtype)


# Matmul weights consumed through dense()/serve_einsum (quantizable at
# serving time).  Excluded by design: embedding (gather), router
# (f32 softmax), r_gates/w_if/w_bc/w_dt_* (raw f32 recurrence matmuls),
# conv/a_log/norms (element-wise consumers).
_SERVABLE = frozenset(
    "wq wk wv wo w_gate w_up w_down unembed w_dq w_uq w_dkv w_uk w_uv "
    "w_kr w_in w_out w_gates".split())


def tree_prepare_serving(params: Any, cfg: QuantConfig,
                         predicate=None) -> Any:
    """Quantize matmul weights (incl. stacked layer/expert tensors) in a
    parameter pytree for serving."""
    def default_pred(path: str, leaf) -> bool:
        return leaf.ndim >= 2 and path.split(".")[-1] in _SERVABLE

    pred = predicate or default_pred

    def visit(path, leaf):
        pstr = ".".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        if isinstance(leaf, jax.Array) and pred(pstr, leaf):
            return prepare_serving(leaf, cfg)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def tree_requantize_serving(params: Any, cfg: QuantConfig,
                            predicate=None) -> Any:
    """Re-quantize a parameter pytree to `cfg.bits_w` for serving.

    Like `tree_prepare_serving`, but also accepts trees whose servable
    leaves are ALREADY `QuantizedTensor`s (a serving tree being demoted to
    a low-bit draft tree): those round-trip through `quant.requantize`,
    float servable leaves quantize directly, everything else (embedding,
    norms, recurrence matmuls) passes through untouched."""
    def default_pred(path: str, leaf) -> bool:
        if isinstance(leaf, quant.QuantizedTensor):
            return True
        return leaf.ndim >= 2 and path.split(".")[-1] in _SERVABLE

    pred = predicate or default_pred

    def visit(path, leaf):
        pstr = ".".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        if isinstance(leaf, quant.QuantizedTensor) and pred(pstr, leaf):
            # same (…, in, out) layout contract as prepare_serving
            nd = len(leaf.shape)
            return quant.requantize(leaf, cfg.bits_w, axis=nd - 2,
                                    pack=cfg.bits_w < 8, pack_axis=-2)
        if isinstance(leaf, jax.Array) and pred(pstr, leaf):
            return prepare_serving(leaf, cfg)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params,
                                            is_leaf=lambda x: isinstance(
                                                x, quant.QuantizedTensor))
