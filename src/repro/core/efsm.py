"""eFSM cycle model — MAC2 latency, port-busy cycles, pipelining (Fig 4/5).

Derivation (verified against the paper's reported numbers):

BRAMAC-2SA, n-bit signed MAC2 (Fig 4): 2 copy cycles (W1, W2 via the two
ports) + 1 cycle (W1+W2, P init) + 1 cycle (MSB invert) + n add/shift cycles
+ 1 accumulate cycle = n + 5 total.  The eFSM overlaps the next MAC2's
2 copy cycles with the last 2 cycles of the current one (Fig 5a), so the
pipelined issue interval is  **n + 3**  → 5 / 7 / 11 cycles for 2/4/8-bit ✓.
Unsigned inputs skip the invert cycle → n + 2.

BRAMAC-1DA (Fig 5b): dummy array double-pumped at 2× clock.  1 main-clock
read + ½ cycle copy + (n + 3) compute half-cycles; the read of the next pair
overlaps compute, so the pipelined interval is  **ceil((n + 4) / 2)**
→ 3 / 4 / 6 cycles for 2/4/8-bit ✓ (unsigned: ceil((n + 3) / 2)).

Main-BRAM port-busy cycles per MAC2: 2 (2SA: one copy cycle per port-pair
per array) / 1 (1DA: both ports issue the two row addresses in one cycle).
Accumulator readout between dot products: 8 (2SA: 2 arrays × 160 b / 40 b)
/ 4 (1DA: 160 b / 40 b) busy cycles.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.quant import SUPPORTED_BITS

ROW_BITS = 160          # dummy array columns == main BRAM physical columns
PORT_BITS = 40          # per-port data width (max-width simple dual port)


def _check(bits):
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"precision must be in {SUPPORTED_BITS}")


@dataclasses.dataclass(frozen=True)
class Variant:
    """One BRAMAC variant's static timing/area parameters."""
    name: str
    dummy_arrays: int            # 2 for 2SA, 1 for 1DA
    double_pumped: bool
    block_area_overhead: float   # vs baseline M20K (Table II)
    core_area_overhead: float
    fmax_mhz: float              # CIM-mode frequency (§V-C / §VI-A)
    port_busy_per_mac2: int      # main-BRAM busy cycles per MAC2 issue

    def mac2_latency(self, bits: int, signed: bool = True) -> int:
        """Pipelined MAC2 issue interval in main-BRAM clock cycles."""
        _check(bits)
        compute = bits + (3 if signed else 2)     # (+sum/init, +invert, n adds)
        if not self.double_pumped:
            return compute                         # copy hidden by pipelining
        return math.ceil((compute + 1) / 2)        # +1 half-cycle copy, 2x clk

    def mac2_lanes(self, bits: int) -> int:
        """MAC2s issued in parallel per instruction (all dummy arrays)."""
        _check(bits)
        return (PORT_BITS // bits) * self.dummy_arrays

    def macs_in_parallel(self, bits: int) -> int:
        """Table II '# of MACs in parallel' (each MAC2 = 2 MACs)."""
        return 2 * self.mac2_lanes(bits)

    def readout_busy_cycles(self) -> int:
        """Main-BRAM busy cycles to drain the accumulator row(s)."""
        return self.dummy_arrays * ROW_BITS // PORT_BITS

    def max_dot_product_macs(self, bits: int) -> int:
        """MACs accumulable before the accumulator row must be drained.

        Paper §IV-C: 16 / 256 / 2048 for 2/4/8-bit.  Accumulator widths are
        8/16/27-bit (Table II footnote; 27-bit matches the DSP accumulator);
        each MAC contributes up to 2^(2·bits) in magnitude →
        capacity = 2^acc_bits / 2^(2·bits).
        """
        _check(bits)
        acc_bits = {2: 8, 4: 16, 8: 27}[bits]
        return 2 ** (acc_bits - 2 * bits)

    def macs_per_cycle(self, bits: int, signed: bool = True) -> float:
        return self.macs_in_parallel(bits) / self.mac2_latency(bits, signed)


BRAMAC_2SA = Variant("BRAMAC-2SA", dummy_arrays=2, double_pumped=False,
                     block_area_overhead=0.338, core_area_overhead=0.068,
                     fmax_mhz=586.0, port_busy_per_mac2=2)
BRAMAC_1DA = Variant("BRAMAC-1DA", dummy_arrays=1, double_pumped=True,
                     block_area_overhead=0.169, core_area_overhead=0.034,
                     fmax_mhz=500.0, port_busy_per_mac2=1)

VARIANTS = {v.name: v for v in (BRAMAC_2SA, BRAMAC_1DA)}
