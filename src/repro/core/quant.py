"""Symmetric low-precision quantization + digit decomposition (BRAMAC §III).

BRAMAC supports 2's complement 2/4/8-bit MAC.  This module provides:

  * symmetric per-channel quantization to n ∈ {2, 4, 8} bits,
  * bit-packing of sub-byte tensors into int8 storage ("main BRAM" layout),
  * the radix-4 *digit* decomposition used by the hybrid bit-serial &
    bit-parallel dataflow: a 2's-complement n-bit integer x decomposes into
    n/2 base-4 digits d_j ∈ {0..3} with the most-significant digit carrying
    negative weight on its top bit:

        x = -4^(n/2-1) * 2 * msb2(d_top) + ...   (handled as signed top digit)

    We use the equivalent form actually implemented in the kernels:
        x = sum_j 4^j * d_j            for unsigned x
        x = (as above) - 2^n * sign    for signed (top bit negative), i.e.
        signed top digit dt ∈ {-2,-1,0,1} = d_top - 4*(d_top>=2).

All functions are pure jnp and jit-safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

SUPPORTED_BITS = (2, 4, 8)


def qrange(bits: int) -> tuple[int, int]:
    """Symmetric signed range for n-bit 2's complement, e.g. 8-bit → [-128, 127]."""
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A quantized tensor: int8 storage (possibly bit-packed) + scale.

    values: int8 array. If packed, several sub-byte elements per int8
            along `packed_axis`.
    scale:  f32, broadcastable to the logical (unpacked) shape.
    bits:   2, 4, or 8.
    packed: whether `values` holds bit-packed sub-byte data.
    shape:  logical (unpacked) shape at creation (informational — unpack
            derives shapes from `values`, so QTs survive scan slicing).

    Registered as a pytree (bits/packed/shape are static aux data) so
    quantized parameter trees flow through jit/checkpoint/sharding — the
    "persistent weights in main BRAM" serving layout.
    """
    values: jax.Array
    scale: jax.Array
    bits: int
    packed: bool
    shape: tuple[int, ...]
    packed_axis: int = -1

    def dequantize(self) -> jax.Array:
        return self.unpacked_values().astype(self.scale.dtype) * self.scale

    def unpacked_values(self) -> jax.Array:
        if not self.packed:
            return self.values
        return unpack_axis(self.values, self.bits, self.packed_axis)


def _qt_unflatten(aux, children):
    bits, packed, shape, packed_axis = aux
    values, scale = children
    return QuantizedTensor(values, scale, bits, packed, shape, packed_axis)


jax.tree_util.register_pytree_with_keys(
    QuantizedTensor,
    lambda qt: (((jax.tree_util.GetAttrKey("values"), qt.values),
                 (jax.tree_util.GetAttrKey("scale"), qt.scale)),
                (qt.bits, qt.packed, qt.shape, qt.packed_axis)),
    _qt_unflatten)


def _check_bits(bits: int) -> None:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"BRAMAC supports bits in {SUPPORTED_BITS}, got {bits}")


def quantize(x: jax.Array, bits: int, axis: int | None = -1,
             pack: bool = False, pack_axis: int = -1) -> QuantizedTensor:
    """Symmetric quantization of x to n-bit 2's complement.

    axis: channel axis for per-channel scales (None = per-tensor).
    pack: bit-pack sub-byte values along `pack_axis`.
    """
    _check_bits(bits)
    lo, hi = qrange(bits)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / hi
    q = jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int8)
    if pack and bits < 8:
        return QuantizedTensor(pack_bits_axis(q, bits, pack_axis),
                               scale.astype(jnp.float32),
                               bits, True, x.shape, pack_axis)
    return QuantizedTensor(q, scale.astype(jnp.float32), bits, False, x.shape)


def requantize(qt: QuantizedTensor, bits: int, axis: int | None = -1,
               pack: bool = False, pack_axis: int = -1) -> QuantizedTensor:
    """Re-quantize an already-quantized tensor to a (usually lower) width.

    Dequantize → quantize: the only faithful route between symmetric
    grids whose scales differ per channel.  Requantizing to the SAME
    width is idempotent up to scale rounding; dropping width (8→2) is
    how a serving tree becomes a cheap draft tree.
    """
    return quantize(qt.dequantize(), bits, axis=axis, pack=pack,
                    pack_axis=pack_axis)


def pack_bits(q: jax.Array, bits: int) -> jax.Array:
    """Pack sub-byte signed ints along the last axis into int8 storage.

    4-bit: 2 per byte; 2-bit: 4 per byte.  Matches the BRAMAC "main BRAM"
    dense storage that gives it 100% utilization at 2/4/8-bit (Fig 10).
    """
    _check_bits(bits)
    if bits == 8:
        return q.astype(jnp.int8)
    per = 8 // bits
    if q.shape[-1] % per:
        raise ValueError(f"last dim {q.shape[-1]} not divisible by {per}")
    u = (q.astype(jnp.int32) & ((1 << bits) - 1)).astype(jnp.uint8)
    u = u.reshape(*q.shape[:-1], q.shape[-1] // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits).astype(jnp.uint8)
    packed = jnp.zeros(u.shape[:-1], jnp.uint8)
    for j in range(per):
        packed = packed | (u[..., j] << shifts[j])
    return packed.astype(jnp.int8)


def unpack(packed: jax.Array, bits: int, shape: tuple[int, ...]) -> jax.Array:
    """Inverse of pack_bits; returns int8 with sign-extension (§III-C2's mux)."""
    _check_bits(bits)
    if bits == 8:
        return packed.astype(jnp.int8)
    per = 8 // bits
    u = packed.astype(jnp.uint8)
    parts = []
    mask = (1 << bits) - 1
    for j in range(per):
        parts.append((u >> (j * bits)) & mask)
    v = jnp.stack(parts, axis=-1).reshape(shape).astype(jnp.int32)
    # sign extension: values >= 2^(bits-1) are negative
    v = jnp.where(v >= (1 << (bits - 1)), v - (1 << bits), v)
    return v.astype(jnp.int8)


def pack_bits_axis(q: jax.Array, bits: int, axis: int) -> jax.Array:
    """pack_bits along an arbitrary axis (moveaxis → pack → moveaxis)."""
    if axis in (-1, q.ndim - 1):
        return pack_bits(q, bits)
    moved = jnp.moveaxis(q, axis, -1)
    return jnp.moveaxis(pack_bits(moved, bits), -1, axis)


def unpack_axis(packed: jax.Array, bits: int, axis: int) -> jax.Array:
    """Inverse of pack_bits_axis; logical shape derived from `packed`."""
    per = 8 // bits
    if axis in (-1, packed.ndim - 1):
        shape = packed.shape[:-1] + (packed.shape[-1] * per,)
        return unpack(packed, bits, shape)
    moved = jnp.moveaxis(packed, axis, -1)
    shape = moved.shape[:-1] + (moved.shape[-1] * per,)
    return jnp.moveaxis(unpack(moved, bits, shape), -1, axis)


def num_digits(bits: int) -> int:
    """Radix-4 digit count = ceil(bits/2); BRAMAC pairs two bits per pass."""
    return (bits + 1) // 2


@partial(jax.jit, static_argnames=("bits", "signed"))
def to_radix4_digits(q: jax.Array, bits: int, signed: bool = True) -> jax.Array:
    """Decompose n-bit ints into radix-4 digits, least-significant first.

    Returns int8 array of shape (num_digits, *q.shape).
    For signed inputs the TOP digit is signed in {-2..1} (2's complement MSB
    carries negative weight — Algorithm 1 line 5); lower digits ∈ {0..3}.

    Invariant:  sum_j 4^j * digits[j] == q  (exactly, in int32).
    """
    _check_bits(bits)
    nd = num_digits(bits)
    x = q.astype(jnp.int32)
    u = x & ((1 << bits) - 1)  # reinterpret as unsigned n-bit
    digits = []
    for j in range(nd):
        d = (u >> (2 * j)) & 0x3
        if signed and j == nd - 1:
            # top digit: its high bit is the sign bit of the n-bit number
            d = jnp.where(d >= 2, d - 4, d)
        digits.append(d.astype(jnp.int8))
    return jnp.stack(digits, axis=0)


def from_radix4_digits(digits: jax.Array) -> jax.Array:
    """Recompose (for tests): sum_j 4^j * digits[j]."""
    nd = digits.shape[0]
    w = (4 ** jnp.arange(nd, dtype=jnp.int32)).reshape((nd,) + (1,) * (digits.ndim - 1))
    return jnp.sum(digits.astype(jnp.int32) * w, axis=0)


@partial(jax.jit, static_argnames=("bits", "signed"))
def to_bits(q: jax.Array, bits: int, signed: bool = True) -> jax.Array:
    """Pure bit-serial decomposition (one bit per plane), LSB first.

    MSB plane is in {-1, 0} for signed inputs (Algorithm 1's subtraction).
    Invariant: sum_i 2^i * planes[i] == q.
    """
    _check_bits(bits)
    x = q.astype(jnp.int32)
    u = x & ((1 << bits) - 1)
    planes = []
    for i in range(bits):
        b = (u >> i) & 1
        if signed and i == bits - 1:
            b = -b
        planes.append(b.astype(jnp.int8))
    return jnp.stack(planes, axis=0)
