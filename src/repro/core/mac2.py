"""Algorithm 1 — Hybrid Bit-Serial & Bit-Parallel MAC2, faithful JAX port.

    P = W1*I1 + W2*I2   (all 2's complement n-bit, n ∈ {2, 4, 8})

The paper's dataflow (BRAMAC §III-B, Fig 3/4):

  * A 7-row "dummy array" holds rows
        [0] zero     [1] W1      [2] W2      [3] W1+W2
        [4] Inverter [5] P       [6] Accumulator
  * For input bit i from MSB down to LSB, the bit-pair {I2[i], I1[i]}
    selects one of rows 0..3 as `psum` (a 4-entry LUT — this is what makes
    the dataflow *bit-parallel* across the whole 160-bit row).
  * If i is the MSB: P += ~psum + 1 (2's complement subtraction, using the
    Inverter row), else P += psum.  If i != LSB: P <<= 1.
  * After the LSB pass, P holds the MAC2 result; row 6 accumulates multiple
    MAC2s of a long dot product in place.

This module implements the loop bit-exactly (including the inverter-based
subtraction) with `jax.lax` control flow, vectorized so that W1/W2 are whole
rows ("lanes") exactly like the 160-bit SIMD adder operating on sign-extended
lanes.  It is the semantic oracle for the Pallas kernels and the cycle model.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import SUPPORTED_BITS

__all__ = ["mac2", "mac2_reference", "mac2_mvm", "lane_width"]


def lane_width(bits: int) -> int:
    """Sign-extended lane width per element (§III-C2): 8/16/32 for 2/4/8-bit.

    A n-bit MAC2 needs at most 2n+1 bits; the sign-extension mux provides
    8/16/32-bit lanes so sequential MAC2s can be accumulated in-place (row 6).
    """
    return {2: 8, 4: 16, 8: 32}[bits]


@partial(jax.jit, static_argnames=("bits", "signed_inputs"))
def mac2(w1: jax.Array, w2: jax.Array, i1: jax.Array, i2: jax.Array,
         bits: int, signed_inputs: bool = True) -> jax.Array:
    """Faithful Algorithm 1. w1/w2: int arrays (lanes), i1/i2: scalars or
    arrays broadcastable against lanes. Returns int32 P = w1*i1 + w2*i2.

    The loop runs over input bits MSB→LSB; each iteration does the 4-way row
    select and one bit-parallel add, matching the eFSM schedule.
    """
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}")
    w1 = w1.astype(jnp.int32)          # sign-extension mux: lanes widened
    w2 = w2.astype(jnp.int32)
    i1 = jnp.asarray(i1, jnp.int32)
    i2 = jnp.asarray(i2, jnp.int32)
    # dummy-array rows 0..3: the LUT  {0, W1, W2, W1+W2}
    zero = jnp.zeros_like(w1)
    lut = jnp.stack([zero, w1, w2, w1 + w2], axis=0)

    u1 = i1 & ((1 << bits) - 1)        # unsigned bit views of the inputs
    u2 = i2 & ((1 << bits) - 1)

    def body(i, p):
        # i counts n-1 downto 0
        b1 = (u1 >> i) & 1
        b2 = (u2 >> i) & 1
        sel = b2 * 2 + b1              # {I2[i], I1[i]} → demux select
        # 2-to-4 demux row select, per lane (row read of the dummy array)
        sel_b = jnp.broadcast_to(sel, p.shape)
        lut_b = jnp.broadcast_to(lut, (4,) + p.shape)
        psum = jnp.take_along_axis(lut_b, sel_b[None].astype(jnp.int32), axis=0)[0]
        is_msb = jnp.logical_and(i == bits - 1, signed_inputs)
        # MSB: P += inv(psum) + 1   (row 4, the Inverter, then +1 carry-in)
        # else P += psum
        add = jnp.where(is_msb, (~psum) + 1, psum)
        p = p + add
        # shift left unless LSB
        p = jnp.where(i != 0, p << 1, p)
        return p

    p0 = jnp.zeros(jnp.broadcast_shapes(w1.shape, jnp.shape(i1)), jnp.int32)
    p = jax.lax.fori_loop(0, bits, lambda k, p: body(bits - 1 - k, p), p0)
    return p


def mac2_reference(w1, w2, i1, i2):
    """Direct integer oracle."""
    return (jnp.asarray(w1, jnp.int32) * jnp.asarray(i1, jnp.int32)
            + jnp.asarray(w2, jnp.int32) * jnp.asarray(i2, jnp.int32))


@partial(jax.jit, static_argnames=("bits", "signed_inputs"))
def mac2_mvm(w: jax.Array, x: jax.Array, bits: int,
             signed_inputs: bool = True) -> jax.Array:
    """Matrix-vector multiply via chained MAC2s (paper Fig 2).

    w: (rows, cols) int weights; x: (cols,) int inputs; cols must be even.
    Column pairs (2k, 2k+1) are issued as MAC2s sharing the input pair
    (x[2k], x[2k+1]); results accumulate in the Accumulator row (row 6).
    Returns int32 (rows,) = w @ x.
    """
    rows, cols = w.shape
    if cols % 2:
        raise ValueError("mac2_mvm needs an even number of columns (MAC2 pairs)")
    wp = w.astype(jnp.int32).reshape(rows, cols // 2, 2)
    xp = x.astype(jnp.int32).reshape(cols // 2, 2)

    def one_pair(k, acc):
        p = mac2(wp[:, k, 0], wp[:, k, 1], xp[k, 0], xp[k, 1],
                 bits=bits, signed_inputs=signed_inputs)
        return acc + p                  # in-place accumulation, row 6 → row 7

    acc0 = jnp.zeros((rows,), jnp.int32)
    return jax.lax.fori_loop(0, cols // 2, one_pair, acc0)
