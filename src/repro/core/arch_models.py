"""Arria-10 resource/throughput/utilization models (Table I/II, Fig 7/9/10).

All constants are from the paper unless marked DERIVED; derivations are
documented inline.  This mirrors the paper's own methodology: Figs 9–13 are
analytical-model results, not silicon measurements, so the reproduction is
exact up to the constants the paper does not tabulate.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.efsm import Variant

# ---------------------------------------------------------------------------
# Baseline FPGA: Arria-10 GX900, fastest speed grade (Table I)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Arria10:
    logic_blocks: int = 33_920        # LABs (Table I)
    dsps: int = 1_518                 # Table I
    brams: int = 2_423                # M20K count of GX900 (Intel tables).
    #   Table I's BRAM row reads "33920" — a PDF extraction artifact
    #   (duplicated from the LB row); the GX900 datasheet value is 2423,
    #   consistent with the 20.1% area ratio.
    lb_area_ratio: float = 0.704
    dsp_area_ratio: float = 0.095
    bram_area_ratio: float = 0.201

    # Frequencies (§VI-A): Quartus-generated
    m20k_fmax_mhz: float = 645.0      # simple dual-port M20K
    dsp_fmax_mhz: float = 549.0       # m18x18_sumof2 mode

    @property
    def dsp_rel_area(self) -> float:
        """DSP area in units of one M20K (from the Table I area ratios)."""
        return (self.dsp_area_ratio / self.dsps) / \
               (self.bram_area_ratio / self.brams)


ARRIA10 = Arria10()

# DSP packing (§VI-A, [36]): each of the two 18×19 multipliers implements
# one 8-bit, two 4-bit, or four 2-bit MACs.
DSP_MACS_PER_MULT = {2: 4, 4: 2, 8: 1}

# DERIVED: soft-logic (LB) MAC throughput in MAC/s for the whole device.
# The paper synthesizes one MAC/precision in Quartus and scales to all LBs
# ("optimistically assuming that all LBs can be used at the same Fmax") but
# does not tabulate the raw numbers.  We invert them from the paper's
# reported total-boost ratios, which over-determine the three unknowns:
#   2-bit: (LB+6.67T+22.72T)/(LB+6.67T)=2.6  → LB = 7.53 TMAC/s
#          cross-check 1DA: (14.2T+16.15T)/14.2T = 2.14 ≈ 2.1 ✓
#   4-bit: …=2.3 → LB = 2.92 TMAC/s; 1DA check: 1.97 ≈ 2.0 ✓
#   8-bit: …=1.9 → LB = 1.20 TMAC/s; 1DA check: 1.70 = 1.7 ✓
LB_TOTAL_MACS_PER_S = {2: 7.53e12, 4: 2.92e12, 8: 1.20e12}


# ---------------------------------------------------------------------------
# Competing architectures (Table II)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BitSerialBram:
    """CCB / CoMeFa: bit-serial compute-in-BRAM (160 lanes, transposed)."""
    name: str
    fmax_slowdown: float              # vs baseline M20K (§VI-A)
    block_area_overhead: float        # Table II
    # MAC latency in cycles (unsigned multiply + psum accumulate), Table II:
    mac_latency: tuple[int, int, int] = (16, 42, 113)   # 2/4/8-bit
    lanes: int = 160

    @property
    def fmax_mhz(self) -> float:
        return ARRIA10.m20k_fmax_mhz / self.fmax_slowdown

    def mac_cycles(self, bits: int) -> int:
        return dict(zip((2, 4, 8), self.mac_latency))[bits]

    def macs_per_cycle(self, bits: int) -> float:
        return self.lanes / self.mac_cycles(bits)


CCB = BitSerialBram("CCB", fmax_slowdown=1.6, block_area_overhead=0.168)
COMEFA_D = BitSerialBram("CoMeFa-D", fmax_slowdown=1.25,
                         block_area_overhead=0.254)
COMEFA_A = BitSerialBram("CoMeFa-A", fmax_slowdown=2.5,
                         block_area_overhead=0.081)


@dataclasses.dataclass(frozen=True)
class LowPrecisionDsp:
    """eDSP / PIR-DSP baselines (Table II)."""
    name: str
    macs_per_block: dict  # per precision
    fmax_mhz: float
    block_area_overhead: float


EDSP = LowPrecisionDsp("eDSP", {2: 8, 4: 8, 8: 4}, 549.0, 0.12)
PIR_DSP = LowPrecisionDsp("PIR-DSP", {2: 24, 4: 12, 8: 6}, 549.0 / 1.3, 0.28)


# ---------------------------------------------------------------------------
# Fig 9: peak MAC throughput
# ---------------------------------------------------------------------------

def dsp_throughput(bits: int, fpga: Arria10 = ARRIA10) -> float:
    """Baseline DSP MAC/s: 2 multipliers per DSP × packing × Fmax."""
    return fpga.dsps * 2 * DSP_MACS_PER_MULT[bits] * fpga.dsp_fmax_mhz * 1e6


def lb_throughput(bits: int) -> float:
    return LB_TOTAL_MACS_PER_S[bits]


def bram_throughput(arch, bits: int, fpga: Arria10 = ARRIA10) -> float:
    """MAC/s contributed by compute-capable BRAM blocks."""
    if isinstance(arch, Variant):                 # BRAMAC
        return fpga.brams * arch.macs_per_cycle(bits) * arch.fmax_mhz * 1e6
    if isinstance(arch, BitSerialBram):           # CCB / CoMeFa
        return fpga.brams * arch.macs_per_cycle(bits) * arch.fmax_mhz * 1e6
    return 0.0


def peak_throughput(bits: int, bram_arch=None, dsp_arch=None,
                    fpga: Arria10 = ARRIA10) -> dict:
    """Fig 9: total peak MAC throughput breakdown for one configuration."""
    if dsp_arch is None:
        dsp = dsp_throughput(bits, fpga)
    else:
        dsp = fpga.dsps * dsp_arch.macs_per_block[bits] * dsp_arch.fmax_mhz * 1e6
    lb = lb_throughput(bits)
    bram = bram_throughput(bram_arch, bits, fpga) if bram_arch else 0.0
    return {"lb": lb, "dsp": dsp, "bram": bram, "total": lb + dsp + bram}


def throughput_boost(bits: int, bram_arch, fpga: Arria10 = ARRIA10) -> float:
    """Enhanced-FPGA peak throughput / baseline peak throughput."""
    base = peak_throughput(bits, None, None, fpga)["total"]
    enh = peak_throughput(bits, bram_arch, None, fpga)["total"]
    return enh / base


# ---------------------------------------------------------------------------
# Fig 10: BRAM utilization efficiency for DNN model storage
# ---------------------------------------------------------------------------

M20K_ROWS = 128   # physical rows of the main array


def bramac_utilization(p: int) -> float:
    """BRAMAC stores weights densely; odd precisions sign-extend to 4/8-bit."""
    stored = 2 if p <= 2 else 4 if p <= 4 else 8
    return p / stored


def comefa_utilization(p: int) -> float:
    """CoMeFa (one-operand-outside): per compute column, scratch rows hold
    the 2p-bit product and (2p+4)-bit partial sum; the rest store weights."""
    overhead = 2 * p + (2 * p + 4)
    return max(0, M20K_ROWS - overhead) / M20K_ROWS


def ccb_utilization(p: int, pack: int) -> float:
    """CCB additionally keeps `pack` input-element copies resident
    (pack-k computes k sequential MACs before pausing for input writes)."""
    overhead = pack * p + 2 * p + (2 * p + 4)
    return max(0, M20K_ROWS - overhead) / M20K_ROWS


def utilization_table(precisions=range(2, 9)) -> dict:
    return {
        "BRAMAC": [bramac_utilization(p) for p in precisions],
        "CCB-Pack-2": [ccb_utilization(p, 2) for p in precisions],
        "CCB-Pack-4": [ccb_utilization(p, 4) for p in precisions],
        "CoMeFa": [comefa_utilization(p) for p in precisions],
    }


def utilization_advantage() -> dict:
    """Average (over 2–8 bit) BRAMAC advantage; paper: 1.3× CCB, 1.1× CoMeFa."""
    t = utilization_table()
    avg = {k: sum(v) / len(v) for k, v in t.items()}
    ccb = (avg["CCB-Pack-2"] + avg["CCB-Pack-4"]) / 2
    return {"vs_ccb": avg["BRAMAC"] / ccb,
            "vs_comefa": avg["BRAMAC"] / avg["CoMeFa"],
            "averages": avg}


# ---------------------------------------------------------------------------
# Fig 7: adder design study (COFFE-derived constants from the paper)
# ---------------------------------------------------------------------------

ADDERS = {
    # name: (delay @32-bit [ps], area [um^2, ~equal per Fig 7b], power [uW])
    "RCA": {"delay_32b_ps": 393.6, "power_uw": 11.3},
    "CBA": {"delay_32b_ps": 139.6, "power_uw": 50.2},
    "CLA": {"delay_32b_ps": 157.6, "power_uw": 17.6},
}


def adder_delay_ps(kind: str, bits: int) -> float:
    """Scaling model: RCA delay ∝ n (ripple); CBA/CLA ∝ n/4 stages of a
    4-bit carry chain / lookahead group, anchored at the paper's 32-bit
    values."""
    anchor = ADDERS[kind]["delay_32b_ps"]
    if kind == "RCA":
        return anchor * bits / 32.0
    stages = math.ceil(bits / 4)
    return anchor * stages / 8.0


DUMMY_ARRAY_AREA_UM2 = 975.6        # §V-C
DUMMY_ARRAY_AREA_OVERHEAD = 0.169   # 16.9% of an M20K per dummy array
EFSM_AREA_UM2 = {"BRAMAC-2SA": 137.0, "BRAMAC-1DA": 81.0}  # 22nm-scaled
