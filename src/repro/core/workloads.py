"""CNN workloads for the DLA case study (§VI-D): AlexNet and ResNet-34.

Each conv layer is described by its GEMM-equivalent dimensions used by the
DLA cycle model: output spatial (H_out, W_out), output channels K,
input channels C, and filter taps R×S.  FC layers are 1×1 convs on a 1×1
feature map.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    h_out: int
    w_out: int
    k: int        # output channels
    c: int        # input channels (per group)
    r: int        # filter height
    s: int        # filter width

    @property
    def macs(self) -> int:
        return self.h_out * self.w_out * self.k * self.c * self.r * self.s

    @property
    def weights(self) -> int:
        return self.k * self.c * self.r * self.s


ALEXNET = (
    ConvLayer("conv1", 55, 55, 96, 3, 11, 11),
    ConvLayer("conv2", 27, 27, 256, 48, 5, 5),
    ConvLayer("conv3", 13, 13, 384, 256, 3, 3),
    ConvLayer("conv4", 13, 13, 384, 192, 3, 3),
    ConvLayer("conv5", 13, 13, 256, 192, 3, 3),
    ConvLayer("fc6", 1, 1, 4096, 256, 6, 6),
    ConvLayer("fc7", 1, 1, 4096, 4096, 1, 1),
    ConvLayer("fc8", 1, 1, 1000, 4096, 1, 1),
)


def _resnet_stage(name, n, h, k, c_first):
    layers = []
    for i in range(n):
        c_in = c_first if i == 0 else k
        layers.append(ConvLayer(f"{name}_{i}a", h, h, k, c_in, 3, 3))
        layers.append(ConvLayer(f"{name}_{i}b", h, h, k, k, 3, 3))
    return layers


RESNET34 = tuple(
    [ConvLayer("conv1", 112, 112, 64, 3, 7, 7)]
    + _resnet_stage("layer1", 3, 56, 64, 64)
    + [ConvLayer("layer2_ds", 28, 28, 128, 64, 1, 1)]
    + _resnet_stage("layer2", 4, 28, 128, 64)
    + [ConvLayer("layer3_ds", 14, 14, 256, 128, 1, 1)]
    + _resnet_stage("layer3", 6, 14, 256, 128)
    + [ConvLayer("layer4_ds", 7, 7, 512, 256, 1, 1)]
    + _resnet_stage("layer4", 3, 7, 512, 256)
    + [ConvLayer("fc", 1, 1, 1000, 512, 1, 1)]
)

MODELS = {"alexnet": ALEXNET, "resnet34": RESNET34}
