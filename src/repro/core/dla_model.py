"""DLA / DLA-BRAMAC cycle-accurate model + design-space exploration (§VI-D).

DLA (Aydonat et al. [9]) is a 1-D systolic CNN accelerator parameterized by
(Qvec, Cvec, Kvec) — parallelism in output-width, input-depth, and
output-depth.  DLA-BRAMAC adds Qvec2 extra output columns computed by the
BRAMAC-enhanced filter cache (Fig 12c): the stream buffer broadcasts input
features to both the PE array and the filter-cache BRAMACs, which compute
`Qvec2` additional outputs along the Q dimension.

Cycle model (per conv layer, output-stationary sweep):
    cycles = H_out · ceil(W_out / Qvec_total) · ceil(C / Cvec)
                   · ceil(K / Kvec) · (R · S)
BRAMAC's weight-copy pipeline is hidden by the eFSM except for the first
MAC2 of each layer (+2 cycles, §VI-D); the accumulator readout is amortized
across the dot product (included via an efficiency factor on the BRAMAC
columns).

Resource model:
  * DSPs  = Qvec1 · Cvec · Kvec · 1.5 / pack(p)   [DERIVED: this exactly
    reproduces every DSP count in Table III, e.g. 8-bit AlexNet (3,12,24) →
    864·1.5 = 1296 ✓; the 1.5 is DLA's PE-array overhead for Winograd/
    reduction logic, folded into an effective DSPs-per-MAC factor]
  * BRAMAC compute blocks: enough blocks that the filter cache sustains
    Qvec2·Cvec·Kvec MACs/cycle at the variant's MACs-per-cycle rate, with
    each block's weight lanes matched to the (Cvec · R · S) dot products.
  * Storage BRAMs: stream buffer (double-buffered input/output tiles) +
    filter cache (weights for Kvec output channels, double-buffered).

The DSE sweeps (Qvec or Qvec1+Qvec2, Cvec, Kvec) under the GX900 resource
budget (1518 DSPs / 2423 BRAMs) maximizing the paper's target
perf · (perf/area), where area is the utilized DSP-plus-BRAM area with
BRAMAC's block overhead applied (Fig 13b).
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from repro.core.arch_models import ARRIA10, DSP_MACS_PER_MULT
from repro.core.efsm import BRAMAC_1DA, BRAMAC_2SA, Variant
from repro.core.workloads import MODELS, ConvLayer

M20K_BITS = 20 * 1024
DSP_PER_MAC_FACTOR = 1.5      # DERIVED from Table III (see module docstring)


# ---------------------------------------------------------------------------
# Resource model
# ---------------------------------------------------------------------------

def dsp_count(qvec1: int, cvec: int, kvec: int, bits: int) -> int:
    if qvec1 == 0:
        return 0
    macs = qvec1 * cvec * kvec
    return math.ceil(macs * DSP_PER_MAC_FACTOR / DSP_MACS_PER_MULT[bits])


def storage_brams(cvec: int, kvec: int, bits: int, layers) -> int:
    """Stream buffer + filter cache storage blocks.

    DLA keeps feature maps on chip (stream buffer holds the in/out pair of
    the largest conv layer) and caches the weights of the largest conv layer
    (FC weights are streamed from DRAM).  This reproduces the magnitude of
    Table III's baseline BRAM counts (e.g. ResNet-34 8-bit ≈ 1.4k blocks).
    """
    convs = [l for l in layers if (l.h_out, l.w_out) != (1, 1)]
    max_w = max(l.weights for l in convs) * bits
    max_fmap = max((l.h_out * l.w_out * l.k) for l in convs) * bits * 2
    return math.ceil(max_w / M20K_BITS) + math.ceil(max_fmap / M20K_BITS)


def bramac_blocks(qvec2: int, cvec: int, kvec: int, bits: int,
                  variant: Variant) -> int:
    """Compute blocks so the filter cache sustains Qvec2·Cvec·Kvec MACs/cyc."""
    if qvec2 == 0:
        return 0
    need = qvec2 * cvec * kvec                     # MACs per cycle
    rate = variant.macs_per_cycle(bits)            # per block
    return math.ceil(need / rate)


@dataclasses.dataclass(frozen=True)
class Config:
    qvec1: int        # output columns on the DSP PE array
    qvec2: int        # output columns on BRAMAC (0 for baseline DLA)
    cvec: int
    kvec: int
    bits: int
    variant: Variant | None = None

    @property
    def qvec(self) -> int:
        return self.qvec1 + self.qvec2

    def resources(self, layers) -> tuple[int, int]:
        dsps = dsp_count(self.qvec1, self.cvec, self.kvec, self.bits)
        brams = storage_brams(self.cvec, self.kvec, self.bits, layers)
        if self.qvec2:
            brams += bramac_blocks(self.qvec2, self.cvec, self.kvec,
                                   self.bits, self.variant)
        return dsps, brams

    def area(self, layers) -> float:
        """Utilized DSP-plus-BRAM area in units of one baseline M20K."""
        dsps, brams = self.resources(layers)
        bram_area = 1.0
        if self.qvec2:
            bram_area = 1.0 + self.variant.block_area_overhead
        return dsps * ARRIA10.dsp_rel_area + brams * bram_area


# ---------------------------------------------------------------------------
# Cycle model
# ---------------------------------------------------------------------------

def layer_cycles(cfg: Config, layer: ConvLayer) -> int:
    c = layer.h_out * math.ceil(layer.w_out / cfg.qvec) \
        * math.ceil(layer.c / cfg.cvec) * math.ceil(layer.k / cfg.kvec) \
        * (layer.r * layer.s)
    if cfg.qvec2:
        c += 2        # first MAC2 weight copy of the layer (§VI-D)
    return c


def model_cycles(cfg: Config, layers) -> int:
    return sum(layer_cycles(cfg, l) for l in layers)


# ---------------------------------------------------------------------------
# Design-space exploration
# ---------------------------------------------------------------------------

_QVECS = tuple(range(1, 33))
_CVECS = (1, 2, 3, 4, 6, 8, 12, 16, 22, 24, 32, 48, 64)
_KVECS = tuple(range(8, 161, 2))


def _candidate_perf(cfg: Config, layers) -> tuple[float, float]:
    cycles = model_cycles(cfg, layers)
    perf = 1.0 / cycles
    return perf, perf * perf / cfg.area(layers)


def max_qvec2(variant: Variant, bits: int) -> int:
    """Structural Qvec2 limit (matches every Table III config).

    2SA's two dummy arrays copy the same weights but take different input
    streams (§IV-A input sharing) → two extra output columns.  1DA has one
    dummy array → one column; at 2-bit its lanes are cheap enough that the
    paper's configs replicate weights across a second block group → two.
    """
    if variant.dummy_arrays == 2:
        return 2
    return 2 if bits == 2 else 1


def explore(model: str, bits: int, variant: Variant | None = None,
            dsp_budget: int = ARRIA10.dsps,
            bram_budget: int = ARRIA10.brams) -> tuple[Config, dict]:
    """DSE maximizing perf·(perf/area) under the resource budget."""
    layers = MODELS[model]
    best, best_score = None, -1.0
    qvec2s = (0,) if variant is None else \
        tuple(range(1, max_qvec2(variant, bits) + 1))
    for cvec, kvec in itertools.product(_CVECS, _KVECS):
        for q1 in _QVECS:
            for q2 in qvec2s:
                if q2 and variant is None:
                    continue
                cfg = Config(q1, q2, cvec, kvec, bits, variant)
                dsps, brams = cfg.resources(layers)
                if dsps > dsp_budget or brams > bram_budget:
                    continue
                perf, score = _candidate_perf(cfg, layers)
                if score > best_score:
                    best, best_score = cfg, score
    dsps, brams = best.resources(layers)
    stats = {"cycles": model_cycles(best, layers), "dsps": dsps,
             "brams": brams, "area": best.area(layers)}
    return best, stats


def case_study(models=("alexnet", "resnet34"), precisions=(2, 4, 8)) -> dict:
    """Fig 13: speedup and area of DLA-BRAMAC vs DLA per (model, precision)."""
    out = {}
    for model in models:
        for bits in precisions:
            base_cfg, base = explore(model, bits, None)
            row = {"dla": (base_cfg, base)}
            for variant in (BRAMAC_2SA, BRAMAC_1DA):
                cfg, stats = explore(model, bits, variant)
                stats["speedup"] = base["cycles"] / stats["cycles"]
                stats["rel_area"] = stats["area"] / base["area"]
                stats["perf_per_area"] = stats["speedup"] / stats["rel_area"]
                row[variant.name] = (cfg, stats)
            out[(model, bits)] = row
    return out


def average_speedups(results: dict | None = None) -> dict:
    """Headline numbers (paper: AlexNet 2.05×/1.7×, ResNet-34 1.33×/1.52×)."""
    results = results or case_study()
    avg = {}
    for model in ("alexnet", "resnet34"):
        for vname in ("BRAMAC-2SA", "BRAMAC-1DA"):
            sp = [results[(model, b)][vname][1]["speedup"] for b in (2, 4, 8)]
            ar = [results[(model, b)][vname][1]["rel_area"] for b in (2, 4, 8)]
            avg[(model, vname)] = {"speedup": sum(sp) / 3,
                                   "rel_area": sum(ar) / 3}
    return avg
