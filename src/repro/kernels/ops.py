"""Public jit'd entry points for the BRAMAC kernels.

`quant_matmul` handles block padding and CPU-interpret dispatch so callers
never touch pallas directly.  `bramac_dense` is the training-friendly
fake-quant (QAT) matmul with a straight-through-estimator VJP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import ref
from repro.kernels.bramac_matmul import bramac_matmul


def _interpret() -> bool:
    """Pallas interpret mode for non-TPU backends, resolved per call — not
    frozen at import, so `jax.config.update("jax_platform_name", ...)` after
    importing this module still selects the right dispatch."""
    return jax.default_backend() != "tpu"


def _pad_to(x, m, axis, value=0):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


VMEM_BUDGET = 16 * 2**20          # v5e VMEM per core (bytes)


def kernel_vmem_bytes(block: tuple[int, int, int], w_packed: bool = False,
                      out_bytes: int = 4) -> int:
    """VMEM working set of one bramac_matmul grid point: activation tile
    (int8) + resident weight tile (int8, halved when 4-bit-packed — the
    dummy-array footprint) + int32 accumulator + output tile.  Block shapes
    must keep this under VMEM_BUDGET with headroom for double-buffering
    (×2 on the streamed operands)."""
    bm, bk, bn = block
    x = bm * bk                   # int8
    w = bk * bn // (2 if w_packed else 1)
    acc = bm * bn * 4
    out = bm * bn * out_bytes
    return 2 * x + 2 * w + acc + out   # ×2: grid-pipeline double buffers


def pick_block(M: int, K: int, N: int) -> tuple[int, int, int]:
    """Largest MXU-friendly blocks that don't over-pad small operands."""
    def pick(d, cap=128, floor=8):
        b = min(cap, max(floor, d))
        while d % b and b > floor:  # prefer a divisor to avoid padding
            b //= 2
        return b
    return pick(M), pick(K), pick(N)


def quant_matmul(x_q, w_q, x_scale, w_scale, *, bits_a: int, bits_w: int,
                 signed: bool = True, out_dtype=jnp.float32,
                 w_packed: bool = False, use_kernel: bool = True):
    """Quantized (M,K)x(K,N) matmul via the BRAMAC Pallas kernel.

    Pads to block multiples, runs the kernel (interpret mode off-TPU), and
    slices back. When use_kernel=False runs the pure-jnp digit reference
    (useful under jit-of-vmap where pallas interpret mode is slow).
    """
    # interpret is resolved here (call/trace time) and enters the jit cache
    # as a static arg, so flipping the backend after import retraces.
    return _quant_matmul(x_q, w_q, x_scale, w_scale, bits_a=bits_a,
                         bits_w=bits_w, signed=signed, out_dtype=out_dtype,
                         w_packed=w_packed, use_kernel=use_kernel,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bits_a", "bits_w", "signed",
                                             "out_dtype", "w_packed",
                                             "use_kernel", "interpret"))
def _quant_matmul(x_q, w_q, x_scale, w_scale, *, bits_a: int, bits_w: int,
                  signed: bool, out_dtype, w_packed: bool, use_kernel: bool,
                  interpret: bool):
    M, K = x_q.shape
    N = w_q.shape[-1]
    if not use_kernel:
        return ref.quant_matmul_digit_ref(
            x_q, w_q, x_scale, w_scale, bits_a=bits_a, signed=signed,
            out_dtype=out_dtype)

    bm, bk, bn = pick_block(M, K, N)
    xp = _pad_to(_pad_to(x_q, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    if w_packed:
        # pack along K (pack_bits packs the last axis → transpose twice);
        # lo nibble of byte r = W[2r], hi nibble = W[2r+1] (kernel contract)
        wp = quant.pack_bits(wp.T, bits_w).T
    xs = _pad_to(jnp.broadcast_to(jnp.asarray(x_scale, jnp.float32), (M, 1)),
                 bm, 0, value=1.0)
    ws = _pad_to(jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (1, N)),
                 bn, 1, value=1.0)
    out = bramac_matmul(xp, wp, xs, ws, bits_a=bits_a, bits_w=bits_w,
                        signed=signed, block=(bm, bk, bn),
                        out_dtype=out_dtype, w_packed=w_packed,
                        interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Training-facing fake-quant dense with straight-through estimator.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def bramac_dense(x, w, bits_w: int, bits_a: int, use_kernel: bool = False):
    """y = dequant(Q(x) · Q(w)) with STE gradients.

    Forward runs the integer BRAMAC dataflow (per-row activation scales,
    per-column weight scales).  Backward treats quantization as identity.
    """
    y, _ = _bramac_dense_fwd(x, w, bits_w, bits_a, use_kernel)
    return y


def _bramac_dense_fwd(x, w, bits_w, bits_a, use_kernel):
    orig_shape = x.shape
    x2 = x.reshape(-1, x.shape[-1])
    qx = quant.quantize(x2, bits_a, axis=-1)           # per-row
    qw = quant.quantize(w, bits_w, axis=0)             # per-column
    y = quant_matmul(qx.values, qw.values, qx.scale, qw.scale,
                     bits_a=bits_a, bits_w=bits_w,
                     out_dtype=x.dtype, use_kernel=use_kernel)
    return y.reshape(*orig_shape[:-1], w.shape[-1]), (x, w)


def _bramac_dense_bwd(bits_w, bits_a, use_kernel, res, g):
    x, w = res
    g2 = g.reshape(-1, g.shape[-1]).astype(w.dtype)
    x2 = x.reshape(-1, x.shape[-1])
    dx = (g2 @ w.T).reshape(x.shape).astype(x.dtype)
    dw = (x2.T @ g2).astype(w.dtype)
    return dx, dw


bramac_dense.defvjp(_bramac_dense_fwd, _bramac_dense_bwd)
