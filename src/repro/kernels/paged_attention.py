"""Pallas paged-attention decode kernel: block-table walks, not gathers.

The serving oracle (`attention.paged_view` + `chunk_attention`) materializes
a dense ``(B, max_seq, ...)`` copy of every sequence's pages before
attending, so per-decode-step memory traffic is proportional to ``max_seq``
even for ten-token sequences.  This kernel applies the paper's
small-fixed-array discipline to the decode hot loop: each grid step owns one
sequence, walks that sequence's block table directly, and DMAs one
``(page_size, Hkv, hd)`` K/V tile at a time from the pool into a fixed VMEM
scratch buffer, combining pages with an online softmax.

Kernel invariants (the contract the parity suite pins):

* **Page-bounded gathers** — the page loop runs ``min(n_pages[b],
  ceil(length[b] / page_size))`` iterations, never ``max_seq / page_size``:
  per-step HBM traffic is proportional to the sequence's *live* tokens.
  Table entries at or beyond ``n_pages`` are never read.
* **Online-softmax exactness contract** — scores are computed in fp32 with
  the oracle's exact masking rule (rows at or past ``length`` replaced by
  -1e30 before the running max), and pages are combined with a running
  max + rescaled accumulator.  Outputs match the gather oracle to float
  reassociation error (the sum is associated per-page instead of once over
  ``max_seq``); greedy token streams are asserted bit-identical in
  tests/test_paged_attention_kernel.py.
* **Masks honored** — the kernel is read-only: ownership (`owned`), write
  (`write_mask`) and speculative (`bound`) masks are write-side concerns
  enforced by `attention.paged_update` before the kernel ever runs, so a
  tile read through the table sees exactly the rows those masks admitted.
  A sequence with ``n_pages == 0`` (free slot) reads nothing and returns
  zeros.
* **int8 KV stays int8** — the quantized variant loads int8 K/V tiles plus
  their per-row scales and dequantizes *in-kernel* on the one resident
  tile; no fp copy of the cache is ever materialized (the oracle's
  `decode_attention_q` contract, minus its probability requantization —
  see `paged_decode_q`).

Dispatch follows `kernels/ops.py`: interpret mode is resolved per call via
`_interpret()` and enters the jit cache as a static argument, so the suite
runs the same kernel code on CPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import _interpret

# jax renamed TPUCompilerParams -> CompilerParams across versions; alias
# whichever this container ships (same guard as kernels/bramac_matmul.py).
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _online_update(carry, s, v_tile):
    """One page's online-softmax step: fold fp32 scores ``s`` (Hkv, g, ps)
    and the fp32 value tile ``v_tile`` (Hkv, ps, hd) into the running
    (max, normalizer, accumulator) carry."""
    m, l, acc = carry
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v_tile, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    return m_new, l, acc * corr[..., None] + pv


def _finish(out_ref, carry, H, hd):
    m, l, acc = carry
    l = jnp.where(l > 0, l, 1.0)        # free slot (no pages): emit zeros
    out_ref[0] = (acc / l[..., None]).reshape(H, hd).astype(out_ref.dtype)


def _fp_kernel(tables_ref, n_ref, len_ref, q_ref, k_hbm, v_hbm, out_ref,
               k_scr, v_scr, sems, *, page_size, hkv):
    H, hd = q_ref.shape[1], q_ref.shape[2]
    g, ps = H // hkv, page_size
    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, hd)
    L = len_ref[0]
    n_eff = jnp.minimum(n_ref[0], (L + ps - 1) // ps)

    def body(j, carry):
        pid = tables_ref[0, j]
        ck = pltpu.make_async_copy(k_hbm.at[pid], k_scr, sems.at[0])
        cv = pltpu.make_async_copy(v_hbm.at[pid], v_scr, sems.at[1])
        ck.start()
        cv.start()
        ck.wait()
        cv.wait()
        kt = k_scr[...].astype(jnp.float32).transpose(1, 0, 2)  # (Hkv,ps,hd)
        vt = v_scr[...].astype(jnp.float32).transpose(1, 0, 2)
        s = jax.lax.dot_general(q, kt, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        s = s / math.sqrt(hd)
        rows = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        s = jnp.where(rows < L, s, -1e30)       # the oracle's masking rule
        return _online_update(carry, s, vt)

    carry = (jnp.full((hkv, g), -jnp.inf, jnp.float32),
             jnp.zeros((hkv, g), jnp.float32),
             jnp.zeros((hkv, g, hd), jnp.float32))
    carry = jax.lax.fori_loop(0, n_eff, body, carry)
    _finish(out_ref, carry, H, hd)


def _q_kernel(tables_ref, n_ref, len_ref, q_ref, qs_ref, k_hbm, ks_hbm,
              v_hbm, vs_hbm, out_ref, k_scr, ks_scr, v_scr, vs_scr, sems,
              *, page_size, hkv):
    """int8 variant: reproduces `decode_attention_q`'s arithmetic — int8
    score dot with the K row scales factored out, fp32 softmax, V row
    scales folded into the probabilities, probabilities *requantized* to
    int8 for an integer PV dot — with three page walks instead of one
    gather (max, then normalizer + probability row scale at the exact
    final max, then the quantized accumulation).  The extra walks keep
    every partial bit-comparable to the oracle: only the normalizer's
    float association order differs.  Traffic stays proportional to live
    tokens; no fp copy of the cache is ever materialized."""
    H, hd = q_ref.shape[1], q_ref.shape[2]
    g, ps = H // hkv, page_size
    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, hd)   # int8 -> f32
    qs = qs_ref[0].reshape(hkv, g)                         # per-row q scales
    L = len_ref[0]
    n_eff = jnp.minimum(n_ref[0], (L + ps - 1) // ps)

    def load_scores(j):
        """DMA page j's tiles; masked fp32 scores (Hkv, g, ps) exactly as
        the oracle computes them, plus the resident int8 V tile and its
        row scales."""
        pid = tables_ref[0, j]
        cps = [pltpu.make_async_copy(src.at[pid], dst, sems.at[i])
               for i, (src, dst) in enumerate(
                   ((k_hbm, k_scr), (ks_hbm, ks_scr),
                    (v_hbm, v_scr), (vs_hbm, vs_scr)))]
        for c in cps:
            c.start()
        for c in cps:
            c.wait()
        kt = k_scr[...].transpose(1, 0, 2)                      # (Hkv,ps,hd)
        kst = ks_scr[...].transpose(1, 0)                       # (Hkv,ps)
        s = jax.lax.dot_general(q, kt.astype(jnp.float32),
                                (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        s = s * qs[..., None] * kst[:, None, :] / math.sqrt(hd)
        rows = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        s = jnp.where(rows < L, s, -1e30)
        vst = vs_scr[...].transpose(1, 0)                       # (Hkv,ps)
        return s, v_scr[...].transpose(1, 0, 2), vst

    def max_body(j, m):
        s, _, _ = load_scores(j)
        return jnp.maximum(m, jnp.max(s, axis=-1))

    m = jax.lax.fori_loop(0, n_eff, max_body,
                          jnp.full((hkv, g), -jnp.inf, jnp.float32))

    def norm_body(j, carry):
        l, u = carry
        s, _, vst = load_scores(j)
        p = jnp.exp(s - m[..., None])
        return l + jnp.sum(p, axis=-1), \
            jnp.maximum(u, jnp.max(p * vst[:, None, :], axis=-1))

    l, u = jax.lax.fori_loop(0, n_eff, norm_body,
                             (jnp.zeros((hkv, g), jnp.float32),
                              jnp.zeros((hkv, g), jnp.float32)))
    l = jnp.where(l > 0, l, 1.0)        # free slot (no pages): emit zeros
    # _quant_rows' scale over the probability row (probs * V row scales)
    pscale = jnp.maximum(u / l, 1e-6) / 127.0

    def acc_body(j, acc):
        s, vt, vst = load_scores(j)
        p = jnp.exp(s - m[..., None]) / l[..., None] * vst[:, None, :]
        pq = jnp.clip(jnp.round(p / pscale[..., None]),
                      -127, 127).astype(jnp.int32)
        return acc + jax.lax.dot_general(
            pq, vt.astype(jnp.int32), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32)

    acc = jax.lax.fori_loop(0, n_eff, acc_body,
                            jnp.zeros((hkv, g, hd), jnp.int32))
    out = acc.astype(jnp.float32) * pscale[..., None]
    out_ref[0] = out.reshape(H, hd).astype(out_ref.dtype)


def _scalar_specs(max_pages):
    """SMEM specs for (tables, n_pages, lengths) — one sequence's row."""
    return [pl.BlockSpec((1, max_pages), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM)]


def paged_decode(q, k_pool, v_pool, tables, n_pages, lengths):
    """Decode attention straight off the paged pool (fp K/V).

    q: (B, H, hd) roped queries; pools: (P, page_size, Hkv, hd);
    tables: (B, max_pages) i32; n_pages: (B,) i32; lengths: (B,) i32 rows
    each query attends (``position + 1``).  Returns (B, H, hd) in q.dtype.
    """
    return _paged_decode(q, k_pool, v_pool, tables, n_pages, lengths,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_decode(q, k_pool, v_pool, tables, n_pages, lengths, *, interpret):
    B, H, hd = q.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    kern = functools.partial(_fp_kernel, page_size=ps, hkv=Hkv)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=_scalar_specs(tables.shape[1]) + [
            pl.BlockSpec((1, H, hd), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, H, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((ps, Hkv, hd), k_pool.dtype),
                        pltpu.VMEM((ps, Hkv, hd), v_pool.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tables, n_pages, lengths, q, k_pool, v_pool)


def paged_decode_q(q_int8, q_scale, k_pool, k_scales, v_pool, v_scales,
                   tables, n_pages, lengths, out_dtype):
    """int8-KV decode attention off the quantized pool.

    q_int8/q_scale: (B, H, hd) int8 + (B, H) f32 row-quantized queries
    (callers quantize with `attention._quant_rows`, exactly as the oracle
    does); k/v pools: (P, page_size, Hkv, hd) int8 with (P, page_size, Hkv)
    f32 row scales.  Tolerance note vs `decode_attention_q`: the kernel
    replays the oracle's arithmetic step for step, including the int8
    probability requantization before the PV dot (see `_q_kernel`); the
    only divergence left is the softmax normalizer's float association
    order (summed per page here, once over max_seq there), so outputs
    agree to reassociation error and greedy token streams stay identical
    (asserted in the parity suite)."""
    return _paged_decode_q(q_int8, q_scale, k_pool, k_scales, v_pool,
                           v_scales, tables, n_pages, lengths,
                           out_dtype=jnp.dtype(out_dtype).name,
                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def _paged_decode_q(q_int8, q_scale, k_pool, k_scales, v_pool, v_scales,
                    tables, n_pages, lengths, *, out_dtype, interpret):
    B, H, hd = q_int8.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    kern = functools.partial(_q_kernel, page_size=ps, hkv=Hkv)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=_scalar_specs(tables.shape[1]) + [
            pl.BlockSpec((1, H, hd), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, H, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), out_dtype),
        scratch_shapes=[pltpu.VMEM((ps, Hkv, hd), jnp.int8),
                        pltpu.VMEM((ps, Hkv), jnp.float32),
                        pltpu.VMEM((ps, Hkv, hd), jnp.int8),
                        pltpu.VMEM((ps, Hkv), jnp.float32),
                        pltpu.SemaphoreType.DMA((4,))],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tables, n_pages, lengths, q_int8, q_scale,
      k_pool, k_scales, v_pool, v_scales)


# ---------------------------------------------------------------------------
# KV bytes-read accounting (the maxtext decode-microbenchmark currency)
# ---------------------------------------------------------------------------

def kv_row_bytes(cfg) -> int:
    """Bytes one decode step reads per cached KV row, summed over every
    layer that owns a paged pool (attn: K+V heads, int8 rows carry their
    f32 scales; mla: the latent c_kv + k_rope row; xattn/recurrent layers
    hold no paged pool and contribute nothing)."""
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    total = 0
    for spec in cfg.layer_pattern:
        if "mla" in spec:
            total += (cfg.kv_lora_rank + cfg.qk_rope_dim) * itemsize
        elif "attn" in spec and "xattn" not in spec:
            if getattr(cfg, "quant_kv", False):
                total += 2 * cfg.num_kv_heads * (cfg.hd + 4)  # int8 + f32
            else:
                total += 2 * cfg.num_kv_heads * cfg.hd * itemsize
    return total * cfg.n_periods


def decode_read_rows(lengths, page_size: int) -> int:
    """Pool rows ONE decode step touches under the kernel: each live
    sequence reads its allocated pages up to the page holding its last row
    (``ceil(length / page_size)`` tiles of ``page_size`` rows) — the
    page-bounded invariant this module exists for.  `lengths` are the live
    row counts (position + 1) of occupied slots; free slots read nothing."""
    return sum(-(-int(n) // page_size) * page_size for n in lengths if n > 0)


def oracle_read_rows(num_slots: int, max_seq: int) -> int:
    """Pool rows ONE decode step touches under the gather oracle:
    `paged_view` materializes all ``num_slots`` tables to ``max_seq`` rows
    each, live or not — the traffic floor the kernel removes."""
    return num_slots * max_seq
