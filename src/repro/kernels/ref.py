"""Pure-jnp oracles for the BRAMAC kernels.

`quant_matmul_exact` is the ground truth (exact integer matmul + dequant).
`quant_matmul_digit_ref` mirrors the radix-4 digit dataflow of the Pallas
kernel step by step (useful to localize divergence: if digit_ref matches
exact but the kernel doesn't, the bug is in the pallas lowering, not the
algorithm).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import num_digits


def quant_matmul_exact(x_q: jax.Array, w_q: jax.Array,
                       x_scale: jax.Array, w_scale: jax.Array,
                       out_dtype=jnp.float32) -> jax.Array:
    """(M,K) int ⋅ (K,N) int → dequantized (M,N)."""
    acc = jax.lax.dot_general(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


@partial(jax.jit, static_argnames=("bits_a", "signed", "out_dtype"))
def quant_matmul_digit_ref(x_q: jax.Array, w_q: jax.Array,
                           x_scale: jax.Array, w_scale: jax.Array,
                           bits_a: int, signed: bool = True,
                           out_dtype=jnp.float32) -> jax.Array:
    """Radix-4 digit-pass matmul (BRAMAC hybrid dataflow), pure jnp.

    For each base-4 digit j of the activations (two input bits per pass —
    the MAC2 bit-pair), do one bit-parallel integer matmul against the
    resident weights and shift-accumulate.  Top digit of signed inputs
    carries negative weight (Algorithm 1 line 5).
    """
    nd = num_digits(bits_a)
    u = x_q.astype(jnp.int32) & ((1 << bits_a) - 1)
    acc = jnp.zeros((x_q.shape[0], w_q.shape[1]), jnp.int32)
    w = w_q.astype(jnp.int8)
    for j in range(nd):
        d = (u >> (2 * j)) & 0x3
        if signed and j == nd - 1:
            d = jnp.where(d >= 2, d - 4, d)
        part = jax.lax.dot_general(
            d.astype(jnp.int8), w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + part * (4 ** j)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def mac2_mvm_ref(w: jax.Array, x: jax.Array) -> jax.Array:
    """Oracle for the faithful dummy-array MVM kernel: exact w @ x (int32)."""
    return jax.lax.dot_general(
        w.astype(jnp.int32), x.astype(jnp.int32)[:, None],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)[:, 0]
