"""Faithful BRAMAC dummy-array MAC2 kernel (validation kernel).

Emulates the 7-row × lane dummy BRAM array (Fig 3a) as a VMEM scratch buffer
and executes the *exact* eFSM dataflow for an MVM:

  row 0: hard-wired zero           row 4: Inverter (for MSB subtraction)
  row 1: W1 (sign-extended copy)   row 5: P (MAC2 result)
  row 2: W2 (sign-extended copy)   row 6: Accumulator (dot-product acc)
  row 3: W1+W2 (precomputed sum)

For each weight-column pair the kernel copies W1/W2 into rows 1-2 (the
main-array→dummy-array copy), computes row 3 with one adder pass (Cycle 3 of
Fig 4), then streams the shared input bit-pair MSB→LSB: each pass reads one
of rows 0-3 through the 2-to-4 demux select {I2[i], I1[i]}, adds it to P
(via the Inverter row on the MSB pass) and shifts.  P accumulates into
row 6 at the end of each MAC2 (Cycle 9).

The inputs x live in SMEM (scalar memory) — they arrive via the CIM
instruction in the paper, i.e. they are scalars broadcast to all 160 lanes,
not vector data.  This kernel is deliberately structured for fidelity, not
speed; `bramac_matmul.py` is the production kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ZERO, _W1, _W2, _W12, _INV, _P, _ACC = range(7)


def _kernel(x_ref, w_ref, out_ref, dummy, *, bits: int, n_pairs: int,
            signed: bool):
    lanes = dummy.shape[1]
    dummy[_ZERO, :] = jnp.zeros((lanes,), jnp.int32)   # hard-coded zero row
    dummy[_ACC, :] = jnp.zeros((lanes,), jnp.int32)    # reset accumulator

    def mac2_pair(k, _):
        # --- weight copy (main array → dummy array, sign-extension mux) ---
        pair = w_ref[:, pl.dslice(2 * k, 2)]
        dummy[_W1, :] = pair[:, 0].astype(jnp.int32)
        dummy[_W2, :] = pair[:, 1].astype(jnp.int32)
        # --- Cycle 3: row3 = W1 + W2 (one SIMD adder pass), P init ---
        dummy[_W12, :] = dummy[_W1, :] + dummy[_W2, :]
        dummy[_P, :] = jnp.zeros((lanes,), jnp.int32)
        i1 = x_ref[2 * k].astype(jnp.int32) & ((1 << bits) - 1)
        i2 = x_ref[2 * k + 1].astype(jnp.int32) & ((1 << bits) - 1)
        # --- bit-serial passes, MSB → LSB (statically unrolled) ---
        for i in range(bits - 1, -1, -1):
            b1 = (i1 >> i) & 1
            b2 = (i2 >> i) & 1
            sel = b2 * 2 + b1                      # 2-to-4 demux
            psum = dummy[pl.dslice(sel, 1), :][0]
            if i == bits - 1 and signed:
                dummy[_INV, :] = ~psum             # Inverter row
                dummy[_P, :] = dummy[_P, :] + dummy[_INV, :] + 1
            else:
                dummy[_P, :] = dummy[_P, :] + psum
            if i != 0:
                dummy[_P, :] = dummy[_P, :] << 1   # shift-left write-back
        # --- Cycle 9: accumulate P into the Accumulator row ---
        dummy[_ACC, :] = dummy[_ACC, :] + dummy[_P, :]
        return 0

    jax.lax.fori_loop(0, n_pairs, mac2_pair, 0)
    out_ref[:, 0] = dummy[_ACC, :]


@functools.partial(jax.jit,
                   static_argnames=("bits", "signed", "block", "interpret"))
def mac2_mvm_kernel(w: jax.Array, x: jax.Array, *, bits: int,
                    signed: bool = True, block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """MVM w @ x through chained MAC2s on the dummy array.

    w: (R, C) int8 (bits-bit values); x: (C,) int8.  C must be even.
    Returns (R,) int32.
    """
    R, C = w.shape
    if C % 2:
        raise ValueError("columns must pair up for MAC2")
    bl = min(block, R)
    if R % bl:
        raise ValueError(f"rows {R} not divisible by lane block {bl}")
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, n_pairs=C // 2, signed=signed),
        grid=(R // bl,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # x: CIM instr
            pl.BlockSpec((bl, C), lambda i: (i, 0)),          # weight tile
        ],
        out_specs=pl.BlockSpec((bl, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((7, bl), jnp.int32)],      # the dummy array
        interpret=interpret,
    )(x, w)
    return out[:, 0]
