"""BRAMAC production kernel: radix-4 bit-plane quantized matmul (Pallas/TPU).

TPU-native adaptation of BRAMAC's hybrid bit-serial & bit-parallel dataflow
(DESIGN.md §2):

  * the quantized weight tile (bk × bn, int8) is DMA'd HBM→VMEM and stays
    *resident* while activation digits stream through it — the "dummy array";
  * activations are consumed two bits per pass (radix-4 digits — the MAC2
    bit-pair {I2[i], I1[i]}), so 2/4/8-bit activations need 1/2/4 MXU passes;
  * the int32 VMEM accumulator plays the role of the dummy array's
    P/Accumulator rows: digit passes shift-accumulate in place;
  * the top digit of signed activations is accumulated with negative weight —
    Algorithm 1 line 5's inverter-row subtraction;
  * the Pallas grid pipeline double-buffers the next weight tile copy behind
    the current tile's compute — the eFSM overlap of Fig 5 that frees the
    "main BRAM" (HBM) for the rest of the system.

Weights enter as int8 holding n-bit values (optionally packed 2-per-byte for
4-bit — see `w_packed`); scales are applied in a fused epilogue on the last
K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import num_digits

# renamed TPUCompilerParams → CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bk, bn) — MXU-aligned


def _digits(u: jax.Array, j: int, nd: int, signed: bool) -> jax.Array:
    d = (u >> (2 * j)) & 0x3
    if signed and j == nd - 1:
        d = jnp.where(d >= 2, d - 4, d)
    return d.astype(jnp.int8)


def _kernel(x_ref, w_ref, xs_ref, ws_ref, out_ref, acc_ref, *,
            bits_a: int, signed: bool, n_k: int, out_dtype, w_packed: bool,
            bits_w: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk) int8 n-bit vals
    u = x.astype(jnp.int32) & ((1 << bits_a) - 1)   # unsigned bit view
    if w_packed:
        # int4 pair-packed along K: byte b at row r holds W[2r] (lo nibble)
        # and W[2r+1] (hi nibble).  Sum over K is order-invariant, so we
        # compute two half-K matmuls against the even/odd activation columns.
        wp = w_ref[...].astype(jnp.int32)           # (bk//2, bn)
        lo = wp & 0xF
        w_lo = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.int8)
        hi = (wp >> 4) & 0xF
        w_hi = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.int8)
        u_lo, u_hi = u[:, 0::2], u[:, 1::2]
        halves = ((u_lo, w_lo), (u_hi, w_hi))
    else:
        halves = ((u, w_ref[...]),)

    nd = num_digits(bits_a)
    acc = acc_ref[...]
    for uu, ww in halves:
        for j in range(nd):                          # bit-serial digit passes
            d = _digits(uu, j, nd, signed)
            part = jax.lax.dot_general(              # bit-parallel MXU pass
                d, ww, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            acc = acc + part * (4 ** j)              # shift-accumulate (P<<2)
    acc_ref[...] = acc

    @pl.when(k == n_k - 1)
    def _epilogue():                                 # fused dequant epilogue
        r = acc_ref[...].astype(jnp.float32)
        out_ref[...] = (r * xs_ref[...] * ws_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits_a", "bits_w", "signed", "block", "out_dtype",
                     "w_packed", "interpret"))
def bramac_matmul(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                  w_scale: jax.Array, *, bits_a: int, bits_w: int,
                  signed: bool = True, block=DEFAULT_BLOCK,
                  out_dtype=jnp.float32, w_packed: bool = False,
                  interpret: bool = False) -> jax.Array:
    """Quantized matmul  (M,K)·(K,N) → (M,N) via the BRAMAC dataflow.

    x_q:     (M, K) int8 holding bits_a-bit values.
    w_q:     (K, N) int8 (or (K//2, N) pair-packed int8 when w_packed).
    x_scale: (M, 1) or (1, 1) f32 per-row activation scales.
    w_scale: (1, N) or (1, 1) f32 per-column weight scales.
    """
    bm, bk, bn = block
    K = x_q.shape[1]
    M = x_q.shape[0]
    N = w_q.shape[-1]
    if M % bm or K % bk or N % bn:
        raise ValueError(f"shape ({M},{K},{N}) not divisible by block {block}")
    if w_packed and bits_w != 4:
        raise ValueError("packed storage implemented for 4-bit weights")
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    xs = jnp.broadcast_to(x_scale.astype(jnp.float32), (M, 1))
    ws = jnp.broadcast_to(w_scale.astype(jnp.float32), (1, N))

    w_spec = (pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)) if w_packed
              else pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)))

    kernel = functools.partial(
        _kernel, bits_a=bits_a, signed=signed, n_k=n_k, out_dtype=out_dtype,
        w_packed=w_packed, bits_w=bits_w)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # activations
            w_spec,                                            # weights
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),     # x scales
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),     # w scales
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],      # the dummy array
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, xs, ws)
