"""Serving launcher: batched requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --requests 8 --new-tokens 12 [--quant-bits 4] \
        [--shard 4 | --shard data=2,model=4] \
        [--capacity-factor 1.0] [--dispatch per_source] \
        [--sampling top_p --temperature 0.8 --top-p 0.95] \
        [--decode-steps 8] [--prefill-chunk 16] \
        [--kv-layout paged|dense] [--page-size 16] [--num-pages 12] \
        [--decode-kernel auto|on|off] \
        [--prefix-cache on|off] [--prefix-chunk 16] \
        [--prefix-max-chains 4096] \
        [--draft-len 4 --spec-ngram 2 --spec-table 512] \
        [--drafter ngram|model --draft-bits 2 --draft-layers 0] \
        [--role prefill|decode|both --prefill-slots 4 --prefill-pages 16]

All engine knobs funnel into ONE `EngineOptions` bundle
(repro.runtime.options) — the launcher is the reference construction of
the sectioned options surface, and the finish-reason / speculation
summaries below come from the structured `RequestResult`s `Engine.run`
returns.
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.bramac_linear import QuantConfig
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.runtime.options import (DebugOptions, DisaggOptions,
                                   EngineOptions, PagingOptions,
                                   ParallelOptions, PrefixOptions,
                                   ScheduleOptions, SpeculationOptions)
from repro.runtime.sampling import SamplingConfig
from repro.runtime.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--quant-bits", type=int, default=0, choices=(0, 2, 4, 8))
    ap.add_argument("--shard", default="",
                    help="mesh over local devices: an int for model-parallel"
                         " ways, or a composed spec like 'data=2,model=4' /"
                         " '2x4' (empty or 0 = off)")
    ap.add_argument("--capacity-factor", type=float, default=0.0,
                    help="MoE expert-capacity factor (0 = config default, "
                         "%(default)s); lower is lossier but faster")
    ap.add_argument("--dispatch", default="",
                    choices=("", "global", "per_source"),
                    help="MoE EP token dispatch: 'global' exact buffers or "
                         "'per_source' GShard-style lossy fast path "
                         "(empty = config default)")
    ap.add_argument("--sampling", default="greedy",
                    choices=("greedy", "temperature", "top_k", "top_p"),
                    help="on-device sampling method (%(default)s)")
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="softmax temperature for stochastic sampling")
    ap.add_argument("--top-k", type=int, default=0,
                    help="k for --sampling top_k")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass for --sampling top_p")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode steps fused per engine tick: host syncs "
                         "per generated token scale as 1/decode_steps")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt chunk size for admission prefill "
                         "(recurrent archs always use 1)")
    ap.add_argument("--seed", type=int, default=0,
                    help="engine base seed for request sampling streams")
    ap.add_argument("--kv-layout", default="paged",
                    choices=("paged", "dense"),
                    help="KV-cache layout: 'paged' shares a pool of fixed-"
                         "size pages through per-slot block tables, 'dense' "
                         "reserves max_seq rows per slot (%(default)s)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="rows per KV page for --kv-layout paged "
                         "(0 = config default, cfg.page_size)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="total pages in the shared pool (0 = capacity-"
                         "equal to dense: slots * ceil(max_seq/page_size))")
    ap.add_argument("--decode-kernel", default="auto",
                    choices=("auto", "on", "off"),
                    help="pallas paged-decode kernel for Sq=1 reads: walks "
                         "each slot's block table instead of gathering "
                         "max_seq rows ('auto' = on for a TPU backend, "
                         "off elsewhere — interpret mode is slow on CPU)")
    ap.add_argument("--prefix-cache", default="on", choices=("on", "off"),
                    help="share cached prompt prefixes across requests "
                         "(paged layout only; recurrent archs opt out; "
                         "%(default)s)")
    ap.add_argument("--prefix-chunk", type=int, default=0,
                    help="prefix-cache hash granularity in tokens "
                         "(0 = page_size)")
    ap.add_argument("--prefix-max-chains", type=int, default=4096,
                    help="prefix-registry capacity; LRU chains beyond it "
                         "are evicted so host memory stays bounded "
                         "(%(default)s)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical 'system prompt' "
                         "tokens to every request — exercises the prefix "
                         "cache")
    ap.add_argument("--draft-len", type=int, default=0,
                    help="self-speculative draft window per decode step "
                         "(0 = off); greedy streams are bit-identical "
                         "either way, accepted drafts just land several "
                         "tokens per verify pass")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="n-gram order of the speculation drafter")
    ap.add_argument("--spec-table", type=int, default=512,
                    help="per-slot drafter table buckets")
    ap.add_argument("--drafter", default="ngram",
                    choices=("ngram", "model"),
                    help="speculation proposal engine: the online n-gram "
                         "table, or 'model' — the serving weights "
                         "requantized to --draft-bits decoding through a "
                         "private draft KV cache (%(default)s)")
    ap.add_argument("--draft-bits", type=int, default=2,
                    choices=(2, 4, 8),
                    help="draft-model weight/activation precision "
                         "(%(default)s — the BRAMAC 2-bit datapath)")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="truncate the draft model to its first N blocks "
                         "(0 = full depth; must be whole layer-pattern "
                         "periods)")
    ap.add_argument("--role", default="",
                    choices=("", "prefill", "decode", "both"),
                    help="prefill/decode disaggregation: 'both' runs the "
                         "split engine in-process (prefill worker with its "
                         "own page pool, page-granularity KV handoff into "
                         "the decode worker); 'prefill'/'decode' are the "
                         "future multi-process endpoints (empty = "
                         "colocated, no split)")
    ap.add_argument("--prefill-slots", type=int, default=0,
                    help="disagg: prefill-worker slot count (0 = same as "
                         "--slots)")
    ap.add_argument("--prefill-pages", type=int, default=0,
                    help="disagg: prefill-worker pool pages (0 = capacity-"
                         "equal: prefill_slots * ceil(max_seq/page_size))")
    ap.add_argument("--check-invariants", action="store_true",
                    help="cross-check the host page-pool mirror against "
                         "the device allocator after every sync")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.page_size:
        cfg = cfg.replace(page_size=args.page_size)
    if args.quant_bits:
        cfg = cfg.replace(quant=QuantConfig(enabled=True,
                                            bits_w=args.quant_bits,
                                            bits_a=args.quant_bits))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = None
    if args.shard and args.shard != "0":    # "0" = off (PR 1's contract)
        try:
            mesh = shd.build_mesh(args.shard)
        except ValueError as e:
            raise SystemExit(f"--shard {args.shard!r}: {e}")
    options = EngineOptions(
        sampling=SamplingConfig(method=args.sampling,
                                temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p),
        schedule=ScheduleOptions(num_slots=args.slots, max_seq=args.max_seq,
                                 decode_steps=args.decode_steps,
                                 prefill_chunk=args.prefill_chunk,
                                 seed=args.seed),
        paging=PagingOptions(kv_layout=args.kv_layout,
                             num_pages=args.num_pages or None,
                             decode_kernel=None if args.decode_kernel ==
                             "auto" else args.decode_kernel == "on"),
        prefix=PrefixOptions(enabled=args.prefix_cache == "on",
                             chunk=args.prefix_chunk or None,
                             max_chains=args.prefix_max_chains),
        speculation=SpeculationOptions(draft_len=args.draft_len,
                                       ngram=args.spec_ngram,
                                       table=args.spec_table,
                                       drafter=args.drafter,
                                       draft_bits=args.draft_bits,
                                       draft_layers=args.draft_layers
                                       or None),
        parallel=ParallelOptions(mesh=mesh,
                                 capacity_factor=args.capacity_factor
                                 or None,
                                 dispatch=args.dispatch or None),
        disagg=DisaggOptions(enabled=bool(args.role),
                             role=args.role or "both",
                             prefill_slots=args.prefill_slots or None,
                             prefill_pages=args.prefill_pages or None),
        debug=DebugOptions(check_invariants=args.check_invariants))
    rng = np.random.default_rng(0)
    # the context manager releases the process-global sharding ctx even if
    # serving raises mid-run
    with Engine(cfg, params, options=options) as eng:
        shared = rng.integers(0, cfg.vocab_size, size=args.shared_prefix)
        reqs = [eng.submit(np.concatenate([
                    shared, rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(4, 24)))]),
                           args.new_tokens)
                for _ in range(args.requests)]
        t0 = time.perf_counter()    # Request.t_first is perf_counter-based
        results = eng.run()
        dt = time.perf_counter() - t0
        done = sum(r.done for r in reqs)
        toks = sum(len(r.tokens) for r in results)
        ttft = [r.ttft for r in results if r.ttft is not None]
        reasons = collections.Counter(r.finish_reason for r in results)
        print(f"{done}/{len(reqs)} requests done, {toks} tokens in {dt:.1f}s "
              f"({toks / dt:.1f} tok/s, quant="
              f"{'int%d' % args.quant_bits if args.quant_bits else 'off'}, "
              f"sampling={args.sampling})")
        print(f"  {eng.n_syncs} host syncs for {eng.n_generated} tokens "
              f"({eng.n_syncs / max(eng.n_generated, 1):.2f} syncs/tok at "
              f"decode_steps={args.decode_steps}); mean ttft "
              f"{1e3 * float(np.mean(ttft)) if ttft else 0.0:.0f}ms; "
              f"finish reasons "
              f"{{{', '.join(f'{k}: {v}' for k, v in sorted(reasons.items()))}}}")
        st = eng.spec_stats()
        if args.draft_len:
            if st["enabled"]:
                print(f"  speculation: drafter={st['drafter']}, "
                      f"draft_len={st['draft_len']}, "
                      f"{st['accepted']}/{st['drafted']} drafts accepted "
                      f"({100 * st['acceptance_rate']:.0f}%), "
                      f"{eng.n_generated / max(eng.n_ticks, 1):.2f} "
                      f"tokens/tick")
            else:
                print("  speculation: requested but this arch opts out "
                      "(recurrent / cross-attention / MoE)")
        if eng.kv_layout == "paged":
            dense_rows = eng.num_slots * eng.max_seq
            hw_rows = eng.pages_high_water * eng.page_size
            print(f"  kv pool: {eng.pages_high_water}/{eng.num_pages} pages "
                  f"high-water x {eng.page_size} rows = {hw_rows} rows "
                  f"({100 * hw_rows / dense_rows:.0f}% of the dense "
                  f"{dense_rows}-row reservation); "
                  f"{eng.pages_in_use} pages still in use")
            print(f"  kv reads: decode_kernel="
                  f"{'on' if eng.decode_kernel else 'off'}, "
                  f"{eng.kv_bytes_read / max(eng.kv_read_steps, 1):.0f} "
                  f"bytes/step over {eng.kv_read_steps} decode steps "
                  f"({'live-token bounded' if eng.decode_kernel else 'max_seq gather'})")
            if eng.disagg:
                dg = eng.disagg_stats()
                print(f"  disagg: {dg['pages_transferred']} pages "
                      f"transferred in {dg['transfer_rounds']} rounds "
                      f"({dg['transfers_backpressured']} backpressured); "
                      f"decode-worker occupancy "
                      f"{dg['decode_pages_high_water']}/"
                      f"{dg['decode_pages']} pages high-water, prefill "
                      f"pool {dg['prefill_pages_high_water']}/"
                      f"{dg['prefill_pages']} over {dg['prefill_slots']} "
                      f"slots")
            st = eng.prefix_stats()
            if st["enabled"]:
                hist = eng.pool.refcount_hist()
                print(f"  prefix cache: {st['hits']}/{st['hits'] + st['misses']}"
                      f" hits ({100 * st['hit_rate']:.0f}%), "
                      f"{st['tokens_skipped']} prefill tokens skipped "
                      f"({st['chunks_skipped']} chunks), "
                      f"{st['evictions']} evictions, "
                      f"{st['cached_pages']} pages cached; "
                      f"pages-shared high-water "
                      f"{eng.pages_shared_high_water}; refcount hist "
                      f"{{{', '.join(f'{r}: {n}' for r, n in enumerate(hist) if n)}}}")
            else:
                print("  prefix cache: off")
        else:
            print(f"  kv dense: {eng.num_slots} slots x {eng.max_seq} rows "
                  f"reserved up front ({eng.num_slots * eng.max_seq} rows)")


if __name__ == "__main__":
    main()
