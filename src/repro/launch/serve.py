"""Serving launcher: batched requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --requests 8 --new-tokens 12 [--quant-bits 4] \
        [--shard 4 | --shard data=2,model=4] \
        [--capacity-factor 1.0] [--dispatch per_source]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.bramac_linear import QuantConfig
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.runtime.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--quant-bits", type=int, default=0, choices=(0, 2, 4, 8))
    ap.add_argument("--shard", default="",
                    help="mesh over local devices: an int for model-parallel"
                         " ways, or a composed spec like 'data=2,model=4' /"
                         " '2x4' (empty or 0 = off)")
    ap.add_argument("--capacity-factor", type=float, default=0.0,
                    help="MoE expert-capacity factor (0 = config default, "
                         "%(default)s); lower is lossier but faster")
    ap.add_argument("--dispatch", default="",
                    choices=("", "global", "per_source"),
                    help="MoE EP token dispatch: 'global' exact buffers or "
                         "'per_source' GShard-style lossy fast path "
                         "(empty = config default)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.quant_bits:
        cfg = cfg.replace(quant=QuantConfig(enabled=True,
                                            bits_w=args.quant_bits,
                                            bits_a=args.quant_bits))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = None
    if args.shard and args.shard != "0":    # "0" = off (PR 1's contract)
        try:
            mesh = shd.build_mesh(args.shard)
        except ValueError as e:
            raise SystemExit(f"--shard {args.shard!r}: {e}")
    eng = Engine(cfg, params, num_slots=args.slots, max_seq=args.max_seq,
                 mesh=mesh, capacity_factor=args.capacity_factor or None,
                 dispatch=args.dispatch or None)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 24))),
                       args.new_tokens)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"{done}/{len(reqs)} requests done, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, quant="
          f"{'int%d' % args.quant_bits if args.quant_bits else 'off'})")


if __name__ == "__main__":
    main()
