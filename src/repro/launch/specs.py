"""ShapeDtypeStruct input stand-ins + sharding assembly for the dry-run.

Everything here is allocation-free: params/opt-state/caches come from
`jax.eval_shape` over the real init functions, so the dry-run lowers the
exact computation the runtime executes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import archs, get_config
from repro.models import model as M
from repro.optim import adamw


def batch_specs(cfg, shape_name: str) -> dict:
    info = archs.SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    i32 = jnp.int32
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    batch = {}
    if cfg.audio_frontend:
        batch["frame_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                     jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return batch


def input_specs(arch: str, shape_name: str = "train_4k",
                opt_cfg: adamw.AdamWConfig | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step function of
    `arch` × `shape` — weak-type-correct, shardable, no device allocation.

    train:   {params, opt_state, batch}
    prefill: {params, batch, caches}
    decode:  {params, tokens, caches, pos}
    """
    import jax.numpy as _jnp
    cfg = get_config(arch)
    info = archs.SHAPES[shape_name]
    params, opt = state_specs(cfg, opt_cfg or adamw.AdamWConfig())
    batch = batch_specs(cfg, shape_name)
    if info["kind"] == "train":
        return {"params": params, "opt_state": opt, "batch": batch}
    caches = cache_specs(cfg, info["batch"], info["seq"])
    if info["kind"] == "prefill":
        return {"params": params, "batch": batch, "caches": caches}
    return {"params": params, "tokens": batch["tokens"], "caches": caches,
            "pos": jax.ShapeDtypeStruct((info["batch"],), _jnp.int32)}


def state_specs(cfg, opt_cfg: adamw.AdamWConfig):
    """(params, opt_state) ShapeDtypeStructs via eval_shape — no allocation."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(functools.partial(M.init_params, cfg), key)
    opt = jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), params)
    return params, opt


def cache_specs(cfg, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------

def _axes_size(ctx, ax):
    size = 1
    for a in ((ax,) if isinstance(ax, str) else ax):
        size *= ctx.mesh.shape[a]
    return size


def batch_shardings(ctx, specs):
    dp = ctx.rules["batch"]

    def per_leaf(leaf):
        first = dp if leaf.shape[0] % _axes_size(ctx, dp) == 0 else None
        return NamedSharding(ctx.mesh, P(first, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(per_leaf, specs)


def opt_shardings(ctx, opt_specs, params_shardings):
    """m: Q8 blocks sharded over fsdp; v mirrors params; step replicated."""
    fsdp = ctx.rules["fsdp"]
    n = _axes_size(ctx, fsdp)

    def q8_leaf(leaf):
        first = fsdp if leaf.shape[0] % n == 0 else None
        rest = [None] * (leaf.ndim - 1)
        return NamedSharding(ctx.mesh, P(first, *rest))

    out = {"step": NamedSharding(ctx.mesh, P())}
    out["m"] = jax.tree_util.tree_map(q8_leaf, opt_specs["m"])
    # v mirrors the param tree structure exactly
    out["v"] = params_shardings
    return out


def cache_shardings(ctx, cache_specs_tree):
    """Decode-state placement.  Two layouts (rules["cache_layout"]):

    "feat" (baseline): batch over dp, last (feature/head) dim over `model`.
    "seq" (§Perf iteration): batch over dp, the *sequence* dim (2) over
    `model` — keeps each layer's attention reading only its local cache
    slice (partial softmax reduces are tiny) instead of re-gathering the
    whole cache per layer when the feature-dim sharding conflicts with the
    grouped-QK einsum.

    Either way, if batch doesn't divide dp (long_500k B=1), the seq dim
    takes the dp axes instead.
    """
    layout = ctx.rules.get("cache_layout", "feat")
    dp = ctx.rules["batch"]
    dpn = _axes_size(ctx, dp)
    tpn = ctx.mesh.shape["model"]

    def per_leaf(leaf):
        spec = [None] * leaf.ndim
        used_dp = False
        if leaf.ndim >= 2 and leaf.shape[1] % dpn == 0:
            spec[1] = dp
            used_dp = True
        if not used_dp and leaf.ndim >= 3 and leaf.shape[2] % dpn == 0:
            spec[2] = dp          # long-context: shard cache seq over dp
        if layout == "seq":
            if leaf.ndim >= 4 and spec[2] is None and \
                    leaf.shape[2] % tpn == 0:
                spec[2] = "model"
            elif leaf.ndim >= 3 and leaf.shape[-1] % tpn == 0:
                spec[-1] = "model"   # non-attention states keep feat shard
        elif leaf.ndim >= 3 and leaf.shape[-1] % tpn == 0:
            spec[-1] = "model"
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree_util.tree_map(per_leaf, cache_specs_tree)


def replicated(ctx, specs):
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(ctx.mesh, P()), specs)
