"""Production mesh builders (TPU v5e pods).

Import of this module never touches jax device state — meshes are built by
functions only (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples).

    Thin wrapper over `parallel.sharding.build_mesh` (the one mesh
    builder, shared with the serve launcher's --shard specs)."""
    from repro.parallel.sharding import build_mesh
    return build_mesh(data=data, model=model)


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
