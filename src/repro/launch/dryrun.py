import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh both --out results/dryrun

The XLA_FLAGS line above MUST run before any jax import (device count is
locked at first init), which is why this module sets it at line 1-2 and why
nothing else in the package sets it globally.
"""
import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import archs, get_config                      # noqa: E402
from repro.launch import specs as sp                             # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.models import model as M                              # noqa: E402
from repro.optim import adamw                                    # noqa: E402
from repro.parallel import sharding as shd                       # noqa: E402

OPT_CFG = adamw.AdamWConfig()

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLL_RE = re.compile(
    r"= (\w+)\[([\d,]*)\][^ ]* "
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind, from post-SPMD HLO.

    Convention: bytes = result-shape bytes of each collective instruction
    (per device, since compiled.as_text() is the partitioned module)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0) + n * _DTYPE_BYTES.get(dtype, 4)
    return out


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg):
    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, om = adamw.apply(params, opt_state, grads, OPT_CFG)
        return params, opt_state, {"loss": loss, **parts, **om}
    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch, caches):
        return M.prefill(params, batch, cfg, caches)
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, tokens, caches, pos):
        return M.decode_step(params, tokens, cfg, caches, pos)
    return decode_step


def _slstm_scan_correction(cfg, info) -> float:
    """sLSTM's recurrent R·h matmul runs inside an inherently-sequential
    time scan, which even the unrolled-layers probe counts once per layer;
    add the missing (S−1) steps analytically.  (The mamba/mLSTM chunk
    scans' in-loop work is elementwise, ~1.5% of their matmul FLOPs —
    left uncorrected, noted in EXPERIMENTS.md.)"""
    n_slstm = sum(s.startswith("slstm") for s in cfg.layer_pattern) \
        * cfg.n_periods
    if not n_slstm or info["kind"] == "decode" or info["seq"] <= 1:
        return 0.0
    per_step = 2 * info["batch"] * cfg.d_model * 4 * cfg.d_model
    mult = 4 if info["kind"] == "train" else 1   # fwd + remat + bwd(2)
    return float(n_slstm) * (info["seq"] - 1) * per_step * mult


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def lower_cell(cfg, shape: str, mesh, rules=None, quant_bits: int = 0):
    """Build the step fn for one cell and AOT-lower it on `mesh`.

    rules: sharding rule set override (e.g. shd.serve_rules for the
    inference TP profile).  quant_bits: serve with pre-quantized weights
    (the paper's persistent-weights deployment; inference kinds only).
    """
    info = archs.SHAPES[shape]
    ctx = shd.activate(mesh, rules)
    params_s, opt_s = sp.state_specs(cfg, OPT_CFG)
    if quant_bits and info["kind"] != "train":
        from repro.core import bramac_linear as bl
        qcfg = bl.QuantConfig(enabled=True, bits_w=quant_bits, bits_a=8)
        params_s = jax.eval_shape(
            lambda p: bl.tree_prepare_serving(p, qcfg), params_s)
    p_sh = shd.param_shardings(params_s, ctx)
    b_specs = sp.batch_specs(cfg, shape)
    b_sh = sp.batch_shardings(ctx, b_specs)

    if info["kind"] == "train":
        fn = make_train_step(cfg)
        o_sh = sp.opt_shardings(ctx, opt_s, p_sh)
        jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
        return jitted.lower(params_s, opt_s, b_specs)
    if info["kind"] == "prefill":
        c_specs = sp.cache_specs(cfg, info["batch"], info["seq"])
        c_sh = sp.cache_shardings(ctx, c_specs)
        fn = make_prefill_step(cfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                         donate_argnums=(2,))
        return jitted.lower(params_s, b_specs, c_specs)
    B = info["batch"]
    c_specs = sp.cache_specs(cfg, B, info["seq"])
    c_sh = sp.cache_shardings(ctx, c_specs)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    rep = sp.replicated(ctx, tok)
    fn = make_decode_step(cfg)
    jitted = jax.jit(fn, in_shardings=(p_sh, rep, c_sh, rep),
                     donate_argnums=(2,))
    return jitted.lower(params_s, tok, c_specs, pos)


def _cache_seq_rules(multi_pod):
    r = shd.default_rules(multi_pod)
    r["cache_layout"] = "seq"
    return r


def _serve_cache_seq_rules(multi_pod):
    r = shd.serve_rules(multi_pod)
    r["cache_layout"] = "seq"
    return r


VARIANTS = {
    # name: (cfg transform, rules factory(multi_pod), serve quant bits)
    # "baseline" pins the original cumsum dispatch: the §Perf baselines in
    # EXPERIMENTS.md were recorded before sort became the config default.
    "baseline": (lambda c: c.replace(moe_dispatch="cumsum"), None, 0),
    "moe_sort": (lambda c: c.replace(moe_dispatch="sort"), None, 0),
    "serve_tp": (lambda c: c, shd.serve_rules, 0),
    "serve_tp_q8": (lambda c: c, shd.serve_rules, 8),
    "serve_tp_q4": (lambda c: c, shd.serve_rules, 4),
    "q8": (lambda c: c, None, 8),
    "q4": (lambda c: c, None, 4),
    "cache_seq": (lambda c: c, _cache_seq_rules, 0),
    "cache_seq_q8": (lambda c: c, _cache_seq_rules, 8),
    "cache_seq_q4": (lambda c: c, _cache_seq_rules, 4),
    "cache_seq_q8_kv8": (lambda c: c.replace(quant_kv=True),
                         _cache_seq_rules, 8),
    "serve_cache_seq_q4": (lambda c: c, _serve_cache_seq_rules, 4),
    "no_remat": (lambda c: c.replace(remat=False), None, 0),
    "moe_sort_no_remat": (
        lambda c: c.replace(moe_dispatch="sort", remat=False), None, 0),
}


def run_cell(arch: str, shape: str, multi_pod: bool,
             cost_probe: bool = True, variant: str = "baseline") -> dict:
    """Phase 1 (production): scan-over-layers lower + compile → compile
    proof, per-device memory analysis.  Phase 2 (cost probe, single-pod):
    layers unrolled, lower + compile → exact per-device FLOPs / bytes /
    collective traffic (XLA cost analysis counts while-loop bodies ONCE, so
    the scanned module undercounts by ~n_periods; the unrolled module is
    the same computation with exact accounting)."""
    transform, rules_fn, qbits = VARIANTS[variant]
    cfg = transform(get_config(arch))
    rules = rules_fn(multi_pod) if rules_fn else None
    info = archs.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, rules, qbits)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()

    if cost_probe:
        cfg_u = cfg.replace(scan_layers=False)
        t0 = time.time()
        compiled_u = lower_cell(cfg_u, shape, mesh, rules, qbits).compile()
        t_probe = time.time() - t0
        ca = compiled_u.cost_analysis()
        hlo = compiled_u.as_text()
    else:
        t_probe = 0.0
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()

    coll = collective_bytes(hlo)
    coll_total = sum(coll.values())
    flops_dev = float(ca.get("flops", 0.0)) \
        + _slstm_scan_correction(cfg, info) / chips
    bytes_dev = float(ca.get("bytes accessed", 0.0))

    # roofline terms (seconds)
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_total / ICI_BW

    # MODEL_FLOPS (useful-work flops, whole step, global)
    n_active = cfg.active_param_count()
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        model_flops = 6 * n_active * tokens
    elif info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        model_flops = 2 * n_active * tokens
    else:
        tokens = info["batch"]
        model_flops = 2 * n_active * tokens

    hlo_flops_global = flops_dev * chips
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    shd.deactivate()
    return {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "status": "ok", "cost_probe_unrolled": cost_probe,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_probe_s": round(t_probe, 1),
        "memory": {
            "args_bytes_per_dev": ma.argument_size_in_bytes,
            "output_bytes_per_dev": ma.output_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
            "alias_bytes_per_dev": ma.alias_size_in_bytes,
            "peak_est_bytes_per_dev": (ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        },
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll,
        "collective_bytes_total_per_dev": coll_total,
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dominant,
            "model_flops_global": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_ratio": model_flops / max(hlo_flops_global, 1.0),
            "roofline_fraction": t_compute / max(
                t_compute, t_memory, t_coll),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--probe", default="auto", choices=["auto", "on", "off"],
                    help="unrolled cost probe: auto = single-pod only; "
                         "off = scan-module costs (undercounts loop bodies; "
                         "record is flagged)")
    args = ap.parse_args()

    arch_list = list(archs.FULL) if args.arch == "all" else [args.arch]
    shape_list = list(archs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in arch_list:
        for shape in shape_list:
            for multi_pod in meshes:
                tag = f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                if not archs.shape_applicable(arch, shape):
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "status": "skipped",
                           "reason": "long_500k needs sub-quadratic mixing; "
                                     "this arch is pure full-attention "
                                     "(DESIGN.md §5)"}
                    json.dump(rec, open(path, "w"), indent=1)
                    print(f"[skip by design] {tag}")
                    continue
                print(f"[run] {tag}", flush=True)
                try:
                    # cost probe (unrolled) on the single-pod mesh only —
                    # the roofline table is single-pod; multipod proves
                    # the pod axis shards/compiles.
                    probe = {"auto": not multi_pod, "on": True,
                             "off": False}[args.probe]
                    rec = run_cell(arch, shape, multi_pod,
                                   cost_probe=probe,
                                   variant=args.variant)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "error": repr(e),
                           "trace": traceback.format_exc()[-4000:]}
                json.dump(rec, open(path, "w"), indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" frac={r['roofline_fraction']:.2f}"
                             f" compile={rec['compile_s']}s")
                print(f"[done] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
