"""Analytic lower bounds for the roofline's memory term.

XLA `cost_analysis()['bytes accessed']` counts the *full operand* of every
dynamic-update-slice, so a functional KV-cache update appears to read+write
the whole cache per layer even though the compiled code aliases it in
place.  The measured memory term is therefore an upper bound for decode
shapes; this module provides the matching analytic lower bound (weights
once per step at TP width, cache read once, activations touched twice per
layer), reported alongside it in §Roofline.
"""
from __future__ import annotations

from repro.configs import archs, get_config
from repro.launch.mesh import HBM_BW

BYTES = 2  # bf16


def kv_cache_bytes(cfg, batch: int, seq: int) -> int:
    """Global decode-state bytes for one model instance."""
    total = 0
    for spec in cfg.layer_pattern:
        mixer = spec.split("+")[0]
        n = cfg.n_periods
        if mixer in ("attn",):
            total += n * 2 * batch * seq * cfg.num_kv_heads * cfg.hd * BYTES
        elif mixer == "xattn":
            total += n * 2 * batch * cfg.vision_tokens * cfg.num_kv_heads \
                * cfg.hd * BYTES
        elif mixer == "mla":
            total += n * batch * seq * (cfg.kv_lora_rank
                                        + cfg.qk_rope_dim) * BYTES
        elif mixer == "mamba":
            d_in = cfg.mamba_expand * cfg.d_model
            total += n * batch * d_in * (cfg.mamba_d_state * 4
                                         + (cfg.mamba_d_conv - 1) * BYTES)
        elif mixer == "mlstm":
            dp = int(cfg.mlstm_proj_factor * cfg.d_model)
            dh = dp // cfg.num_heads
            total += n * batch * cfg.num_heads * (dh * dh + dh) * 4
        elif mixer == "slstm":
            total += n * batch * cfg.d_model * 4 * 4
    return total


def min_bytes_per_dev(arch: str, shape: str, chips: int = 256,
                      model_par: int = 16, weight_bytes: float = BYTES) -> float:
    """Analytic per-device HBM-bytes floor for one step."""
    cfg = get_config(arch)
    info = archs.SHAPES[shape]
    B, S, kind = info["batch"], info["seq"], info["kind"]
    w = cfg.active_param_count() * weight_bytes / model_par
    if kind == "decode":
        cache = kv_cache_bytes(cfg, B, S) / chips
        return w + cache
    acts = 2 * B * S * cfg.d_model * cfg.num_layers * BYTES / chips
    if kind == "prefill":
        cache = kv_cache_bytes(cfg, B, S) / chips   # written once
        return w + cache + acts
    # train: fwd + remat-fwd + bwd(dx) + bwd(dw) weight passes, grads +
    # optimizer state traffic (int8 m + bf16 v + bf16 params r/w)
    opt = cfg.param_count() * (2 + 2 + 1 + 1 + 2 + 2) / chips
    return 4 * w + opt + 3 * acts


def min_memory_term(arch: str, shape: str, **kw) -> float:
    return min_bytes_per_dev(arch, shape, **kw) / HBM_BW
