"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 50 [--data 2 --model 2] [--quant-bits 8]

Uses the local devices (set XLA_FLAGS=--xla_force_host_platform_device_count=N
in the environment to emulate a mesh on CPU); full configs target the
production meshes via the same code path.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core.bramac_linear import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--quant-bits", type=int, default=0, choices=(0, 2, 4, 8))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.quant_bits:
        cfg = cfg.replace(quant=QuantConfig(enabled=True,
                                            bits_w=args.quant_bits,
                                            bits_a=args.quant_bits))
    mesh = make_host_mesh(args.data, args.model)
    shd.activate(mesh)
    print(f"mesh {dict(mesh.shape)}  arch {cfg.name}  "
          f"params {cfg.param_count() / 1e6:.1f}M")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25,
                         opt=adamw.AdamWConfig(lr=args.lr))
    trainer = Trainer(cfg, tcfg, params)
    trainer.restore_latest()
    pipe = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    hist = trainer.train(pipe, args.steps)
    if hist:
        print(f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
              f"over {len(hist)} steps; straggler events: "
              f"{len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
