"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) d_ff=8192 vocab=2048,
decoder-only over EnCodec tokens; the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings.  [arXiv:2306.05284]"""
from repro.configs.base import ModelConfig

ARCH_ID = "musicgen-large"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio", num_layers=48, d_model=2048,
        num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048,
        layer_pattern=("attn+dense",), audio_frontend=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
        layer_pattern=("attn+dense",), audio_frontend=True, dtype="float32")
