"""Aggregated registry of the 10 assigned architectures."""
from __future__ import annotations

from repro.configs import (dbrx_132b, granite_8b, internlm2_20b,
                           jamba_1_5_large_398b, llama_3_2_vision_11b,
                           minicpm3_4b, musicgen_large, qwen3_moe_30b_a3b,
                           starcoder2_7b, xlstm_1_3b)

_MODULES = (dbrx_132b, qwen3_moe_30b_a3b, jamba_1_5_large_398b, minicpm3_4b,
            internlm2_20b, starcoder2_7b, granite_8b, llama_3_2_vision_11b,
            musicgen_large, xlstm_1_3b)

FULL = {m.ARCH_ID: m.full_config for m in _MODULES}
SMOKE = {m.ARCH_ID: m.smoke_config for m in _MODULES}

# Shape applicability (DESIGN.md §5): long_500k needs sub-quadratic mixers.
SUBQUADRATIC = ("jamba-1.5-large-398b", "xlstm-1.3b")

SHAPES = {
    "train_4k":    {"seq": 4096,    "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768,   "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32768,   "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524288,  "batch": 1,   "kind": "decode"},
}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC
    return True


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch × shape) cells; inapplicable ones are reported
    as skipped-by-design (8 long_500k cells for full-attention archs)."""
    return [(a, s) for a in FULL for s in SHAPES]
