"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
16 experts top-4, fine-grained.  [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig

ARCH_ID = "dbrx-132b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", num_layers=40, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=10752, vocab_size=100352,
        layer_pattern=("attn+moe",), num_experts=16, experts_per_token=4,
        moe_d_ff=10752, rope_theta=500_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=112, vocab_size=256,
        layer_pattern=("attn+moe",), num_experts=4, experts_per_token=2,
        moe_d_ff=112, dtype="float32")
