"""starcoder2-7b [dense]: 32L d4608 36H (GQA kv=4) d_ff=18432 vocab=49152,
RoPE.  [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

ARCH_ID = "starcoder2-7b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=32, d_model=4608,
        num_heads=36, num_kv_heads=4, d_ff=18432, vocab_size=49152,
        layer_pattern=("attn+dense",), rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=72,
        num_heads=6, num_kv_heads=2, d_ff=144, vocab_size=256,
        layer_pattern=("attn+dense",), dtype="float32")
