"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
[arXiv:2403.17297]"""
from repro.configs.base import ModelConfig

ARCH_ID = "internlm2-20b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=48, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=92544,
        layer_pattern=("attn+dense",), rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        layer_pattern=("attn+dense",), dtype="float32")
