"""qwen3-moe-30b-a3b [moe]: 48L d2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-moe-30b-a3b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", num_layers=48, d_model=2048,
        num_heads=32, num_kv_heads=4, d_ff=768, vocab_size=151936,
        layer_pattern=("attn+moe",), num_experts=128, experts_per_token=8,
        moe_d_ff=768, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=256,
        layer_pattern=("attn+moe",), num_experts=8, experts_per_token=2,
        moe_d_ff=48, dtype="float32")
