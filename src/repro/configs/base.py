"""Model/config schema shared by all architectures.

A model is a stack of `num_layers` layers; `layer_pattern` describes one
repeating period as `"<mixer>+<ff>"` entries:

  mixers: attn (GQA+RoPE) | mla | xattn (cross-attention) | mamba
          | mlstm | slstm
  ff:     dense (SwiGLU) | moe | none

The stack scans over `num_layers / len(layer_pattern)` periods with stacked
parameters, which keeps the lowered HLO compact for 40–72-layer models.
"""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp

from repro.core.bramac_linear import QuantConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_pattern: tuple[str, ...] = ("attn+dense",)
    head_dim: int | None = None      # default d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 → d_ff)
    moe_dispatch: str = "sort"       # "sort" (default) | "cumsum"
    #   sort: argsort-based rank-in-expert, O(T·k log T·k), no E-wide
    #   temporaries — adopted as default after the §Perf hillclimb;
    #   cumsum: the original (T·k, E) one-hot cumsum — O(T·E) memory and
    #   quadratic-cost reduce-window lowering at 32k-token scale.  The
    #   §Perf baselines in EXPERIMENTS.md were recorded with "cumsum".
    moe_capacity_factor: float = 1.25
    ep_dispatch: str = "global"      # "global" | "per_source"
    #   global: exact global-capacity buffers (all_gather combine);
    #   per_source: GShard-style per-source capacity C_src = ceil(C/n) with
    #   a mirrored all_to_all combine — lossy fast path, drops decided
    #   shard-locally (see repro.parallel.ep).

    # --- attention ---
    rope_theta: float = 10_000.0
    q_lora_rank: int = 0             # MLA
    kv_lora_rank: int = 0            # MLA
    qk_nope_dim: int = 64            # MLA per-head dims
    qk_rope_dim: int = 32
    v_head_dim: int = 64

    # --- mamba ---
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0           # 0 → ceil(d_model / 16)

    # --- xlstm ---
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 256            # chunkwise scan for mamba/mlstm

    # --- modality frontends (stubs per assignment) ---
    vision_tokens: int = 0           # precomputed patch embeddings (vlm)
    audio_frontend: bool = False     # precomputed frame embeddings (audio)

    # --- execution ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    quant: QuantConfig = QuantConfig(enabled=False)
    quant_kv: bool = False           # int8 KV cache (GQA decode; §Perf)
    page_size: int = 16              # KV-cache page rows ("BRAM-array-sized"
    #                                  blocks): the paged serving layout
    #                                  allocates the cache as a shared pool
    #                                  of fixed (page_size,)-row pages with
    #                                  per-slot block tables instead of a
    #                                  dense [slot, max_seq] reservation
    remat: bool = True
    scan_layers: bool = True         # False: unroll periods (exact HLO cost
    #                                  accounting — scan bodies are counted
    #                                  once by XLA cost analysis)
    logical_rules: str = "default"   # sharding rule set name

    def __post_init__(self):
        if self.num_layers % len(self.layer_pattern):
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"pattern period {len(self.layer_pattern)}")
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads/kv_heads mismatch")
        if self.page_size < 1:
            raise ValueError(f"{self.name}: page_size must be >= 1, "
                             f"got {self.page_size}")

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def n_periods(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for roofline MODEL_FLOPS and memory budgets) ----
    def param_count(self) -> int:
        return sum(_layer_params(self, spec) for spec in self.layer_pattern) \
            * self.n_periods + 2 * self.vocab_size * self.d_model

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only) — the N in
        MODEL_FLOPS = 6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        total = 2 * self.vocab_size * self.d_model
        for spec in self.layer_pattern:
            n = _layer_params(self, spec)
            if spec.endswith("+moe"):
                full_moe = self.num_experts * 3 * self.d_model \
                    * self.expert_d_ff
                active_moe = self.experts_per_token * 3 * self.d_model \
                    * self.expert_d_ff
                n = n - full_moe + active_moe
            total += n * self.n_periods
        return total


def _layer_params(cfg: ModelConfig, spec: str) -> int:
    mixer, ff = spec.split("+")
    d = cfg.d_model
    n = 0
    if mixer in ("attn", "xattn"):
        n += d * cfg.num_heads * cfg.hd + d * cfg.hd * cfg.num_kv_heads * 2 \
            + cfg.num_heads * cfg.hd * d
    elif mixer == "mla":
        qr = cfg.q_lora_rank or d
        n += d * qr + qr * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
        n += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        n += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
        n += cfg.num_heads * cfg.v_head_dim * d
    elif mixer == "mamba":
        d_in = cfg.mamba_expand * d
        dt_rank = cfg.mamba_dt_rank or -(-d // 16)
        n += d * 2 * d_in + d_in * cfg.mamba_d_conv \
            + d_in * (dt_rank + 2 * cfg.mamba_d_state) + dt_rank * d_in \
            + d_in * cfg.mamba_d_state + d_in + d_in * d
    elif mixer == "mlstm":
        dp = int(cfg.mlstm_proj_factor * d)
        n += d * 2 * dp + 3 * dp * dp // max(cfg.num_heads, 1) + dp * d \
            + 2 * dp  # qkv (blockwise), gates, out
    elif mixer == "slstm":
        dp = int(cfg.slstm_proj_factor * d)
        n += 4 * d * d + 2 * d * dp + dp * d
    if ff == "dense":
        n += 3 * d * cfg.d_ff
    elif ff == "moe":
        n += cfg.num_experts * 3 * d * cfg.expert_d_ff + d * cfg.num_experts
    return n
