"""Architecture registry: --arch <id> → ModelConfig (full or smoke)."""
from __future__ import annotations

from repro.configs import (archs)
from repro.configs.base import ModelConfig

FULL = archs.FULL
SMOKE = archs.SMOKE
ARCH_IDS = tuple(FULL.keys())


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE if smoke else FULL
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(table)}")
    return table[arch]()
