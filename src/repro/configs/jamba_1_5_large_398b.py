"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attention 1:7 interleave with MoE every
other layer.  [arXiv:2403.19887]

Period of 8 layers: attention at position 4, Mamba elsewhere; MoE FFN on
odd positions, dense FFN on even — 1 attn : 7 mamba and MoE every 2 ✓.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "jamba-1.5-large-398b"

_PATTERN = tuple(
    ("attn" if i == 4 else "mamba") + "+" + ("moe" if i % 2 else "dense")
    for i in range(8))


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid", num_layers=72, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=24576, vocab_size=65536,
        layer_pattern=_PATTERN, num_experts=16, experts_per_token=2,
        moe_d_ff=24576, mamba_d_state=16, mamba_expand=2, mamba_d_conv=4)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256,
        layer_pattern=_PATTERN, num_experts=4, experts_per_token=2,
        moe_d_ff=96, mamba_d_state=4, mamba_expand=2, mamba_d_conv=4,
        dtype="float32", chunk_size=8)
