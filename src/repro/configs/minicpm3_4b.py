"""minicpm3-4b [dense]: 62L d2560 40H d_ff=6400 vocab=73448, MLA
(multi-head latent attention: q_lora 768, kv_lora 256).
[hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import ModelConfig

ARCH_ID = "minicpm3-4b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=62, d_model=2560,
        num_heads=40, num_kv_heads=40, d_ff=6400, vocab_size=73448,
        layer_pattern=("mla+dense",), q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        layer_pattern=("mla+dense",), q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, dtype="float32")
