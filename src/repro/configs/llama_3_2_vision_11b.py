"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers every 5th layer; the vision
frontend is a STUB — input_specs() provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama-3.2-vision-11b"

_PATTERN = ("xattn+dense",) + ("attn+dense",) * 4   # cross-attn every 5


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm", num_layers=40, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
        layer_pattern=_PATTERN, vision_tokens=1600, rope_theta=500_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm", num_layers=5, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=112, vocab_size=256,
        layer_pattern=_PATTERN, vision_tokens=16, dtype="float32")
