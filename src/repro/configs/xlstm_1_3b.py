"""xlstm-1.3b [ssm]: 48L d2048 4H d_ff=0 (projections live inside the
sLSTM/mLSTM blocks) vocab=50304, xLSTM[7:1] — 7 mLSTM : 1 sLSTM.
[arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

ARCH_ID = "xlstm-1.3b"

_PATTERN = ("mlstm+none",) * 7 + ("slstm+none",)    # xLSTM[7:1]


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm", num_layers=48, d_model=2048,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
        layer_pattern=_PATTERN, mlstm_proj_factor=2.0,
        slstm_proj_factor=4.0 / 3.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
        layer_pattern=_PATTERN, dtype="float32", chunk_size=8)
