"""Fault-tolerant training runtime.

Responsibilities:
  * jit'd train_step (loss + grad + AdamW) with donated state,
  * periodic async checkpointing + pruning,
  * crash recovery: any exception (or injected failure) rolls back to the
    last checkpoint and replays — the data pipeline is stateless so replay
    is bit-identical,
  * straggler watchdog: per-step wall-time EWMA; steps exceeding
    `straggler_factor ×` the EWMA are logged with the step index (on a real
    fleet this triggers hot-spare substitution; the hook is the integration
    point),
  * elastic restore: `Trainer.restore(..., mesh=new_mesh)` re-places every
    leaf for a different topology (checkpoints are path-keyed, not
    device-keyed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.checkpoint import ckpt
from repro.models import model as M
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    async_ckpt: bool = True
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, params, opt_state=None):
        self.cfg, self.tcfg = cfg, tcfg
        self.params = params
        self.opt_state = opt_state or adamw.init(params, tcfg.opt)
        self.step = 0
        self.straggler_events: list[tuple[int, float]] = []
        self._ewma = None
        self._pending_ckpt = None

        def train_step(params, opt_state, batch):
            (loss, parts), grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, batch, cfg), has_aux=True)(params)
            params, opt_state, om = adamw.apply(params, opt_state, grads,
                                                tcfg.opt)
            return params, opt_state, {"loss": loss, **parts, **om}

        self._train_step = jax.jit(train_step, donate_argnums=(0, 1))

    # -- one step with watchdog ------------------------------------------
    def run_step(self, batch) -> dict:
        t0 = time.perf_counter()
        self.params, self.opt_state, metrics = self._train_step(
            self.params, self.opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        if self._ewma is None:
            self._ewma = dt
        elif dt > self.tcfg.straggler_factor * self._ewma and self.step > 3:
            self.straggler_events.append((self.step, dt))
        self._ewma = 0.9 * (self._ewma or dt) + 0.1 * dt
        self.step += 1
        return metrics

    # -- checkpointing ----------------------------------------------------
    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def maybe_checkpoint(self, force=False):
        if force or (self.step and self.step % self.tcfg.ckpt_every == 0):
            if self._pending_ckpt is not None:
                self._pending_ckpt.join()
            self._pending_ckpt = ckpt.save(
                self.tcfg.ckpt_dir, self.step, self.state_tree(),
                blocking=not self.tcfg.async_ckpt)
            ckpt.prune(self.tcfg.ckpt_dir, self.tcfg.keep)

    def restore_latest(self, shardings=None) -> int:
        step = ckpt.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return 0
        tree = ckpt.restore(self.tcfg.ckpt_dir, step, self.state_tree(),
                            shardings=shardings)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        return step

    # -- fault-tolerant loop ----------------------------------------------
    def train(self, pipeline, num_steps: int,
              failure_hook: Callable[[int], None] | None = None,
              max_restarts: int = 3) -> list[dict]:
        """Run to `num_steps`, recovering from exceptions via the last
        checkpoint.  `failure_hook(step)` may raise to inject faults
        (tests use this)."""
        history: list[dict] = []
        restarts = 0
        while self.step < num_steps:
            try:
                while self.step < num_steps:
                    batch = pipeline.batch(self.step)
                    if failure_hook is not None:
                        failure_hook(self.step)
                    history.append(self.run_step(batch))
                    self.maybe_checkpoint()
            except Exception:
                restarts += 1
                if restarts > max_restarts:
                    raise
                # roll back to last durable state and replay
                if self._pending_ckpt is not None:
                    self._pending_ckpt.join()
                    self._pending_ckpt = None
                self.restore_latest()
        self.maybe_checkpoint(force=True)
        if self._pending_ckpt is not None:
            self._pending_ckpt.join()
            self._pending_ckpt = None
        return history
