"""Refcounted KV page allocator with copy-on-write prefix sharing.

This module owns EVERY mutation of the serving engine's shared KV page
pool — the BRAMAC discipline of making the same stored bits serve many
consumers, applied to the cache: a system prompt prefilled once is mapped
read-only into every later request that starts with it, so warm-prefix
admission skips the shared chunks' prefill compute entirely.

Three cooperating pieces:

  PagePool     — the device-resident allocator pytree (per-page refcounts,
                 per-slot block tables, per-slot ownership bits).  All
                 traced mutation goes through `admit_update` (evict →
                 share → grant → register, in that order), `release`
                 (refcount decrement to zero reclaims), `cow_copy`
                 (the copy-on-write split: a shared page's rows are copied
                 into a freshly granted private page inside the jit'd
                 admit, never written in place) and `apply_refs_delta`
                 (bare registry deltas — the commit path for an eviction
                 round that admitted no slot).

  HostPool     — the host-side mirror.  It replays the exact device rules
                 (including the grant order) from the same inputs, so the
                 engine can make backpressure / eviction decisions and
                 know every granted page id WITHOUT a device sync.
                 `Engine(check_invariants=True)` compares the two after
                 every sync point.

  PrefixCache  — the host-side prefix registry: incremental-hash chains
                 of fixed `prefix_chunk`-token prompt prefixes mapped to
                 the pool pages that hold their KV rows.  Keys are
                 chunk-incremental blake2b digests (fixed bytes per chunk
                 instead of O(len^2) raw token bytes), chains hold ONE
                 device reference per distinct page however many chains
                 cover it, and LRU chains are evicted when admission
                 would otherwise stall on a dry pool OR when the registry
                 grows past `max_chains` (host memory stays bounded under
                 high-cardinality traffic).

Invariants (property-tested in tests/test_page_allocator_properties.py):

  I1  refcounts are never negative.
  I2  a page is free iff its refcount is 0: grants draw only from
      refcount-0 pages, and a page returns to the free set exactly when
      its last reference is released.
  I3  sum(n_pages over slots) == sum(refs) - cached_pages: every live
      reference is either a slot's block-table mapping or the single
      cache reference a page with >= 1 registered chains holds.
  I4  grants are deterministic: lowest free page id first, admitting
      slots served in ascending slot order.
  I5  at most one slot owns (may write) any page, and shared mappings are
      never written: attention's paged scatter drops rows aimed at a page
      the writing slot does not own, and divergence inside a shared page
      is resolved by `cow_copy` into a fresh owned page at admission.
  I6  speculative rows never outlive their rejection: a draft window's
      KV writes land only inside the writing slot's owned pages below
      its accepted-length bound (pos + budget — rows a non-speculative
      run could reach), and `rollback` zeroes the rows past the accepted
      position through the same write-mask/ownership/bound discipline
      before the tick's host sync — so the pool a speculative engine
      holds matches what the sequential engine would have written.
  I7  page transfer preserves the allocator discipline across pools:
      moving a request between pools (`export_pages` → `import_pages` +
      `adopt`, the prefill→decode handoff in disaggregated serving)
      copies its pages' contents bit-exactly, grants the destination
      ids by the SAME lowest-free-id rule as admission (I4, replayed by
      the destination HostPool so no sync is needed), marks every
      imported page owned with refcount 1, and releases the source
      references only in the same traced call that read the tiles — so
      after any transfer round BOTH pools independently satisfy I1–I6
      and the moved request's rows read back identical to the rows the
      source pool held.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# device-resident allocator pytree
# ---------------------------------------------------------------------------

class PagePool(NamedTuple):
    """Refcounted page-pool state; one device pytree for all slots.

    refs[p]      — live references to page p: one per slot block-table
                   mapping plus one if any registered prefix chain covers
                   it.  0 means free (I2).
    tables[s, j] — pool page holding slot s's rows
                   [j*page_size, (j+1)*page_size).
    n_pages[s]   — live table entries for slot s.
    owned[s, j]  — slot s may WRITE through entry j (it granted the page
                   or received it via copy-on-write).  Shared prefix
                   mappings are read-only (owned=False); attention's
                   paged scatter enforces this (I5)."""
    refs: jax.Array      # (P,) i32
    tables: jax.Array    # (S, mp) i32
    n_pages: jax.Array   # (S,) i32
    owned: jax.Array     # (S, mp) bool


def init_pool(num_slots: int, table_len: int, num_pages: int) -> PagePool:
    return PagePool(
        refs=jnp.zeros((num_pages,), jnp.int32),
        tables=jnp.zeros((num_slots, table_len), jnp.int32),
        n_pages=jnp.zeros((num_slots,), jnp.int32),
        owned=jnp.zeros((num_slots, table_len), bool))


def free_mask(pool: PagePool) -> jax.Array:
    """(P,) bool — free iff refcount 0 (I2)."""
    return pool.refs == 0


def admit_update(pool: PagePool, admitting, shared, n_shared, new_pages,
                 evict_delta, register_delta) -> PagePool:
    """One admission round of pool bookkeeping, in the fixed order the
    host mirror replays: (1) eviction decrements free idle cached pages,
    (2) shared prefix pages are mapped read-only into table entries
    [0, n_shared) with a refcount bump each, (3) `new_pages[s]` fresh
    pages are granted (lowest free id first, slots in ascending order —
    I4) into entries [n_shared, n_shared + new_pages) with refcount 1 and
    ownership, (4) registration bumps newly cached pages.

    admitting (S,) bool — slots taking a new request this call.
    shared (S, mp) i32  — cache-hit page ids (entries past n_shared[s]
    are ignored).  evict/register_delta (P,) i32 — refcount deltas from
    the host prefix registry (eviction negative, registration positive).
    """
    P = pool.refs.shape[0]
    mp = pool.tables.shape[1]
    refs = pool.refs + evict_delta
    j = jnp.arange(mp, dtype=jnp.int32)[None, :]
    sh_take = admitting[:, None] & (j < n_shared[:, None])
    refs = refs.at[jnp.where(sh_take, shared, P)].add(1, mode="drop")
    # grant AFTER shares bump: a page evicted and re-shared in the same
    # round is no longer free and must not be granted
    order = jnp.argsort(refs != 0, stable=True)       # free ids first, asc
    starts = jnp.cumsum(new_pages) - new_pages        # ascending slot order
    k = j - n_shared[:, None]                         # fresh-grant index
    g_take = admitting[:, None] & (k >= 0) & (k < new_pages[:, None])
    grant = order[jnp.clip(starts[:, None] + k, 0, max(P - 1, 0))] \
        .astype(jnp.int32)
    refs = refs.at[jnp.where(g_take, grant, P)].add(1, mode="drop")
    tables = jnp.where(g_take, grant,
                       jnp.where(sh_take, shared, pool.tables))
    owned = jnp.where(g_take, True, jnp.where(sh_take, False, pool.owned))
    n_pages = jnp.where(admitting, n_shared + new_pages, pool.n_pages)
    return PagePool(refs + register_delta, tables, n_pages, owned)


def apply_refs_delta(pool: PagePool, delta) -> PagePool:
    """Bare refcount delta ((P,) i32) with no table changes — the device
    commit for an eviction round that ended up admitting no slot: the
    host registry already dropped its chains, so the -1 cache refs must
    land here too or the evicted pages leak as phantom-occupied."""
    return pool._replace(refs=pool.refs + delta)


def release(pool: PagePool, dead) -> PagePool:
    """Drop every reference `dead` slots hold (shared and owned alike);
    a page whose refcount hits 0 is thereby free (I2) — cached pages keep
    their registry reference and survive for future prefix hits."""
    P = pool.refs.shape[0]
    j = jnp.arange(pool.tables.shape[1], dtype=jnp.int32)[None, :]
    held = dead[:, None] & (j < pool.n_pages[:, None])
    refs = pool.refs.at[jnp.where(held, pool.tables, P)].add(-1, mode="drop")
    return PagePool(refs, pool.tables,
                    jnp.where(dead, 0, pool.n_pages),
                    pool.owned & ~dead[:, None])


def cow_copy(caches, pool_flags, src, dst):
    """Copy-on-write split, inside the jit'd admit: for every slot s with
    src[s] >= 0, copy page src[s]'s rows into page dst[s] in EVERY shared
    pool leaf (all layers; per-slot leaves untouched).  The source — a
    cached page holding a prefix that diverges from the admitting prompt
    mid-page — is never written in place (I5); rows past the divergence
    point are stale in the copy but stay causally masked until the slot's
    own prefill/decode overwrites them."""
    ok = src >= 0

    def cp(leaf, is_pool):
        if not is_pool:
            return leaf
        P = leaf.shape[1]                  # leaf: (n_periods, P, ps, ...)
        rows = jnp.take(leaf, jnp.clip(src, 0, max(P - 1, 0)), axis=1)
        return leaf.at[:, jnp.where(ok, dst, P)].set(rows, mode="drop")

    return jax.tree_util.tree_map(cp, caches, pool_flags)


def rollback(caches, pool_flags, pv, positions):
    """Zero speculative KV rows the verify pass rejected (I6), inside the
    jit'd tick.  `positions` (S, L) holds the rejected rows' absolute
    positions per slot (the caller routes kept rows to pv.max_seq, which
    drops); `pv` is the attention.PagedKV bundle the window was WRITTEN
    with, so the rollback honours the identical write-mask / ownership /
    bound discipline — it can never touch a shared page, another slot's
    rows, or a row the original write already dropped."""
    ps = pv.page_size
    mp = pv.tables.shape[1]
    pg_idx = positions // ps
    ok = pv.write_mask[:, None] & (pg_idx < pv.n_pages[:, None]) \
        & (positions < pv.max_seq)
    if pv.owned is not None:
        ok &= jnp.take_along_axis(pv.owned, jnp.clip(pg_idx, 0, mp - 1),
                                  axis=1)
    if pv.bound is not None:
        ok &= positions < pv.bound[:, None]
    pid = jnp.take_along_axis(pv.tables, jnp.clip(pg_idx, 0, mp - 1), axis=1)

    def zero(leaf, is_pool):
        if not is_pool:
            return leaf
        P = leaf.shape[1]                  # leaf: (n_periods, P, ps, ...)
        return leaf.at[:, jnp.where(ok, pid, P), positions % ps].set(
            0, mode="drop")

    return jax.tree_util.tree_map(zero, caches, pool_flags)


def export_pages(caches, pool_flags, src_ids):
    """Gather the page tiles at `src_ids` ((mp,) i32, clipped) from every
    shared pool leaf — the read half of a cross-pool transfer (I7).  The
    returned tree mirrors `caches` with the page axis replaced by the mp
    gathered tiles; per-slot leaves come back zero-width so the tree
    structure survives a later `tree_map` against the flags.  Entries
    past the request's real page count gather garbage that the import
    side routes to the drop index, keeping the call shape-stable."""
    def take(leaf, is_pool):
        if not is_pool:
            return leaf[:, :0]
        P = leaf.shape[1]                  # leaf: (n_periods, P, ps, ...)
        return jnp.take(leaf, jnp.clip(src_ids, 0, max(P - 1, 0)), axis=1)

    return jax.tree_util.tree_map(take, caches, pool_flags)


def import_pages(caches, pool_flags, tiles, dst_ids, live):
    """Scatter `export_pages` tiles into this pool's pages `dst_ids`
    ((mp,) i32) — the write half of a cross-pool transfer (I7).  `live`
    ((mp,) bool) marks the real entries; the rest route to the drop
    index.  Contents land bit-exact: tiles were gathered, never
    recomputed."""
    def put(leaf, is_pool, tile):
        if not is_pool:
            return leaf
        P = leaf.shape[1]
        return leaf.at[:, jnp.where(live, dst_ids, P)].set(
            tile.astype(leaf.dtype), mode="drop")

    return jax.tree_util.tree_map(put, caches, pool_flags, tiles)


def adopt(pool: PagePool, slot, page_ids, n) -> PagePool:
    """Install an imported request into `slot`: table entries [0, n) map
    `page_ids` ((mp,) i32) with ownership and one reference each.  The
    caller picks `page_ids` by the destination mirror's admit rules
    (lowest free id first — I4/I7), so the ids are known host-side
    without a sync; `slot` and `n` are traced scalars, one compile
    serves every transfer."""
    S, mp = pool.tables.shape
    P = pool.refs.shape[0]
    live = jnp.arange(mp, dtype=jnp.int32) < n
    refs = pool.refs.at[jnp.where(live, page_ids, P)].add(1, mode="drop")
    take = (jnp.arange(S)[:, None] == slot) & live[None, :]
    return PagePool(
        refs,
        jnp.where(take, page_ids[None, :], pool.tables),
        jnp.where(jnp.arange(S) == slot, n, pool.n_pages),
        jnp.where(take, True, pool.owned))


# ---------------------------------------------------------------------------
# host-side mirror
# ---------------------------------------------------------------------------

class HostPool:
    """Numpy replay of the device allocator.  `admit_round` applies the
    same evict → share → grant → register order and the same grant rule
    (lowest free id first, rounds in the order given, which the engine
    builds in ascending slot order), so every page id the device will
    compute is known on the host without a sync."""

    def __init__(self, num_pages: int, num_slots: int):
        self.num_pages = num_pages
        self.refs = np.zeros(num_pages, np.int32)
        self.slot_tables: list[list[int]] = [[] for _ in range(num_slots)]
        self.slot_owned: list[list[bool]] = [[] for _ in range(num_slots)]

    @property
    def free_pages(self) -> int:
        return int((self.refs == 0).sum())

    @property
    def pages_in_use(self) -> int:
        return int((self.refs > 0).sum())

    @property
    def pages_shared(self) -> int:
        """Pages serving more than one consumer right now."""
        return int((self.refs > 1).sum())

    @property
    def slot_refs_total(self) -> int:
        return sum(len(t) for t in self.slot_tables)

    def refcount_hist(self) -> np.ndarray:
        """hist[r] = number of pages with refcount exactly r."""
        return np.bincount(self.refs, minlength=1)

    def apply_delta(self, delta: dict[int, int]) -> None:
        """Apply a bare registry refcount delta (eviction decrements /
        registration increments) with no table changes — also the commit
        path for an eviction round that ends up admitting no slot."""
        for p, d in delta.items():
            self.refs[p] += d
            assert self.refs[p] >= 0, f"refcount of page {p} went negative"

    def admit_round(self, grants, evict_delta, register_delta=None):
        """grants: [(slot, shared_ids, n_fresh)] in ascending slot order.
        Returns {slot: granted page ids}.  register_delta, when known at
        call time, may also be applied later via `apply_register`."""
        self.apply_delta(evict_delta)
        for _, shared_ids, _ in grants:
            for p in shared_ids:
                self.refs[p] += 1
        free_ids = np.flatnonzero(self.refs == 0)
        need = sum(n for _, _, n in grants)
        assert need <= free_ids.size, \
            f"grant of {need} pages exceeds {free_ids.size} free"
        granted: dict[int, list[int]] = {}
        i = 0
        for slot, shared_ids, n_fresh in grants:
            ids = [int(x) for x in free_ids[i:i + n_fresh]]
            i += n_fresh
            for p in ids:
                self.refs[p] += 1
            self.slot_tables[slot] = list(shared_ids) + ids
            self.slot_owned[slot] = [False] * len(shared_ids) \
                + [True] * n_fresh
            granted[slot] = ids
        if register_delta:
            self.apply_delta(register_delta)
        return granted

    def apply_register(self, register_delta: dict[int, int]) -> None:
        self.apply_delta(register_delta)

    def release_slot(self, slot: int) -> None:
        for p in self.slot_tables[slot]:
            self.refs[p] -= 1
            assert self.refs[p] >= 0, f"refcount of page {p} went negative"
        self.slot_tables[slot] = []
        self.slot_owned[slot] = []


# ---------------------------------------------------------------------------
# host-side prefix registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Chain:
    end: int                   # prefix length in tokens (k * prefix_chunk)
    pages: tuple[int, ...]     # pool pages holding rows [0, end)
    last_use: int              # LRU clock


class PrefixCache:
    """Registry of prefill prefixes at `prefix_chunk`-token granularity.
    A chain for `end` tokens maps the ceil(end/page_size) pages holding
    those rows; the last page may be partial (end not page-aligned), in
    which case consumers receive it via copy-on-write rather than a
    read-only mapping.  Each distinct page carries ONE device/host
    refcount for the cache however many chains cover it.  The registry is
    bounded: beyond `max_chains` chains, registration evicts LRU chains
    so host memory stays finite under high-cardinality traffic."""

    def __init__(self, prefix_chunk: int, page_size: int,
                 max_chains: int = 4096):
        if prefix_chunk < 1:
            raise ValueError(f"prefix_chunk must be >= 1, "
                             f"got {prefix_chunk}")
        if max_chains < 1:
            raise ValueError(f"max_chains must be >= 1, got {max_chains}")
        self.prefix_chunk = prefix_chunk
        self.page_size = page_size
        self.max_chains = max_chains
        self.chains: dict[bytes, _Chain] = {}
        self.page_chains: dict[int, int] = {}     # page -> covering chains
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.tokens_skipped = 0

    @property
    def cached_pages(self) -> int:
        return len(self.page_chains)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def keys_for(self, prompt: np.ndarray) -> tuple[bytes, ...]:
        """Chunk-incremental blake2b digests: keys[i] identifies tokens
        [0, (i+1)*prefix_chunk).  One running hash walks the prompt once,
        so a prompt costs O(len/prefix_chunk) fixed-size keys instead of
        the O(len^2/prefix_chunk) bytes raw-token keys would take."""
        pc = self.prefix_chunk
        h = hashlib.blake2b(digest_size=16)
        keys = []
        for end in range(pc, len(prompt) + 1, pc):
            h.update(prompt[end - pc:end].tobytes())
            keys.append(h.digest())
        return tuple(keys)

    def match(self, keys, prompt_len: int):
        """Longest registered chain among `keys` — the prompt's precomputed
        chunk-prefix hashes (keys[i] covers (i+1)*prefix_chunk tokens) —
        that is a PROPER prefix: matches are capped at prompt_len-1, the
        final prompt token must always be computed since its logits seed
        the first sampled token.

        Pure planning — no stats, no LRU tick: a queue head that fails
        admission (backpressure) re-plans every round, and only `commit`
        (called once, when the request actually admits) records telemetry.

        Returns (matched_tokens, full_page_ids, cow_src, key): full pages
        are mapped read-only; cow_src (or -1) is the partial page whose
        rows the admitting slot must receive as a private copy; key (or
        None) is the matched chain's key, to pass to `commit`."""
        best, best_key = None, None
        for i, key in enumerate(keys):
            if (i + 1) * self.prefix_chunk >= prompt_len:
                break
            c = self.chains.get(key)
            if c is not None and (best is None or c.end > best.end):
                best, best_key = c, key
        if best is None:
            return 0, [], -1, None
        n_full = best.end // self.page_size
        cow = int(best.pages[n_full]) if best.end % self.page_size else -1
        return best.end, list(best.pages[:n_full]), cow, best_key

    def commit(self, key: bytes | None, matched: int) -> None:
        """Record a planned `match`'s telemetry and LRU tick once its
        request actually admitted.  The chain may already be gone — the
        same round's eviction pass can pop it after the match (its pages
        stay alive through the admitting slot's share refs) — so the tick
        is best-effort while the counters always land."""
        if key is None:
            self.misses += 1
            return
        self.hits += 1
        self.tokens_skipped += matched
        c = self.chains.get(key)
        if c is not None:
            c.last_use = self._tick()

    def register(self, keys, table_ids, delta) -> None:
        """Add chains for every chunk-aligned prefix of a just-prefilled
        prompt (keys[i] covers (i+1)*prefix_chunk tokens) whose rows live
        in `table_ids` (the slot's block table).  Pages gaining their
        first covering chain get +1 in `delta` (the single cache
        reference of I3)."""
        for i, key in enumerate(keys):
            end = (i + 1) * self.prefix_chunk
            c = self.chains.get(key)
            if c is not None:
                c.last_use = self._tick()
                continue
            pages = tuple(int(p) for p in table_ids[:-(-end // self.page_size)])
            self.chains[key] = _Chain(end, pages, self._tick())
            for p in pages:
                n = self.page_chains.get(p, 0)
                self.page_chains[p] = n + 1
                if n == 0:
                    delta[p] = delta.get(p, 0) + 1
        # capacity cap: LRU chains beyond max_chains are evicted into the
        # SAME delta, so their cache-ref drops ride the round's register
        # update (host and device stay in lockstep)
        while len(self.chains) > self.max_chains:
            key = min(self.chains, key=lambda k: self.chains[k].last_use)
            self._evict_chain(key, delta)

    def _evict_chain(self, key: bytes, delta, eff=None) -> int:
        """Drop one chain; pages losing their last covering chain get -1
        in `delta` (and in `eff` when given).  Returns how many pages
        thereby became free as judged against `eff` (0 without one)."""
        c = self.chains.pop(key)
        self.evictions += 1
        freed = 0
        for p in c.pages:
            self.page_chains[p] -= 1
            if self.page_chains[p] == 0:
                del self.page_chains[p]
                delta[p] = delta.get(p, 0) - 1
                if eff is not None:
                    eff[p] -= 1
                    if eff[p] == 0:
                        freed += 1
        return freed

    def evict(self, need_free: int, eff: np.ndarray, delta) -> int:
        """Evict LRU chains until `need_free` additional pages would be
        free, judging freeness against `eff` — the mirror refcounts with
        this admission round's pending shares/evictions already applied —
        so idle cached pages are preferred over stalling admission.
        Returns how many pages were actually freed."""
        freed = 0
        while freed < need_free and self.chains:
            key = min(self.chains, key=lambda k: self.chains[k].last_use)
            freed += self._evict_chain(key, delta, eff)
        return freed
