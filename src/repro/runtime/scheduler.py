"""Host-side serving policy: request lifecycle and admission planning.

The engine split (PR 10) puts every HOST decision in this module and
every DEVICE computation in `runtime.workers`:

  Scheduler — owns the FIFO queue, the per-slot request registry, the
              `pages.HostPool` mirror(s), the prefix registry and the
              finished-result list.  `plan_round` is the admission
              policy transplanted from the old Engine._admit: FIFO with
              backpressure, prefix matching, LRU eviction of idle
              cached chains, and the mirror's admit-round replay that
              pins every granted page id host-side (I4) — the returned
              `AdmissionRound` is a pure description the PrefillWorker
              executes.  `plan_transfers` is the disaggregated-mode
              analogue for the prefill→decode handoff: it moves a
              finished prompt's bookkeeping between the two mirrors
              (same lowest-free-id grant rule on the destination — I7)
              and backpressures FIFO when the decode pool is dry or no
              decode slot is free.

Colocated engines alias the two sides: `decode_pool is pool` and
`decode_slot_req is slot_req`, so the single-pool engine runs the exact
code path it always did.  Disaggregated engines call `attach_decode` to
give the decode side its own mirror and slot registry.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.runtime import pages as pg
from repro.runtime.options import RequestResult


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int           # effective budget (clamped to max_seq room)
    seed: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0          # wall time the first token landed (TTFT)
    # prefix-cache keys, hashed once at submit: prefix_keys[i] identifies
    # the (i+1)*prefix_chunk-token prefix of `prompt`
    prefix_keys: tuple = ()
    stop_tokens: tuple = ()       # per-request stop set (engine default or
    #                               the submit(stop_tokens=...) override)
    requested: int = 0            # max_new_tokens as asked (pre-clamp)
    clamped: bool = False         # budget clamped by max_seq at submit
    aborted: bool = False
    prefill_tokens: int = 0       # prompt tokens whose prefill compute ran
    pages_shared: int = 0         # prefix pages mapped read-only at admit
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    result: RequestResult | None = None   # set when the request completes


@dataclasses.dataclass
class AdmissionRound:
    """One admission round, fully decided on the host: which requests
    land in which slots, the page-pool transaction (already replayed in
    the HostPool mirror), and the chunk schedule the PrefillWorker
    executes.  An empty `admitted` with a non-empty `evict_delta` is an
    eviction-only round whose refcount decrements still must land on
    the device pool."""
    admitted: list            # [(slot, Request)] ascending slot order
    plan: dict                # slot -> (m_len, full_ids, cow_src, n_fresh)
    starts: dict              # slot -> first prefill chunk offset
    n_chunks: dict            # slot -> prefill chunk count
    evict_delta: dict         # page -> refcount decrement (registry evict)
    reg_delta: dict           # page -> refcount increment (registration)
    chunks_skipped: int = 0   # warm-prefix chunks admission never ran


@dataclasses.dataclass
class Transfer:
    """One prefill→decode handoff, fully decided on the host: the
    destination ids came from the decode mirror's own lowest-free-id
    grant pass (I7), so the device-side export/import needs no sync."""
    req: Request
    src_slot: int             # prefill-side slot being vacated
    dst_slot: int             # decode-side slot receiving the request
    src_ids: list             # prefill-pool pages, block-table order
    dst_ids: list             # decode-pool pages granted for them
    n: int                    # live pages transferred


class Scheduler:
    """Request lifecycle + admission/transfer policy; no device state."""

    def __init__(self, *, num_slots: int, max_seq: int, page_size: int,
                 prefill_chunk: int, paged: bool, num_pages: int,
                 stop_cap: int, stop_tokens: tuple,
                 prefix: pg.PrefixCache | None):
        self.num_slots = num_slots          # admission-side slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.paged = paged
        self.num_pages = num_pages          # admission-side pool size
        self.stop_cap = stop_cap
        self.stop_tokens = stop_tokens
        self.prefix = prefix
        self.pool: pg.HostPool | None = \
            pg.HostPool(num_pages, num_slots) if paged else None
        self.slot_req: list[Request | None] = [None] * num_slots
        self.queue: list[Request] = []
        self.finished: list[RequestResult] = []
        # colocated default: the decode side IS the admission side (the
        # aliases make every single-pool code path identical to the
        # pre-split engine); attach_decode breaks the alias for disagg
        self.decode_pool = self.pool
        self.decode_slot_req = self.slot_req
        self.decode_pages = num_pages
        self.disagg = False
        # disagg: prefilled requests awaiting their page transfer, FIFO
        self.ready: list[Request] = []
        self._ready_slot: dict[int, int] = {}     # uid -> prefill slot
        self._next_uid = itertools.count()
        # engine-lifetime speculation totals (folded in as requests retire)
        self.tokens_drafted = 0
        self.tokens_accepted = 0
        self.transfers_backpressured = 0

    def attach_decode(self, num_slots: int, num_pages: int) -> None:
        """Give the decode side its own mirror and slot registry
        (disaggregated mode); admission keeps the prefill-side pool."""
        self.decode_pool = pg.HostPool(num_pages, num_slots)
        self.decode_slot_req = [None] * num_slots
        self.decode_pages = num_pages
        self.disagg = True

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------

    def _need_pages(self, prompt_len: int, max_new: int) -> int:
        """Pages a request occupies for its whole lifetime: prompt rows
        plus one KV row per decode step (the first token comes from the
        prefill logits), clipped to the max_seq-1 generation ceiling."""
        rows = min(prompt_len + max_new - 1, self.max_seq - 1)
        return -(-rows // self.page_size)

    def submit(self, prompt, max_new_tokens: int = 16,
               seed: int | None = None,
               stop_tokens: tuple | None = None) -> Request:
        """Queue a prompt; validation and deterministic budget clamping
        (see Engine.submit, which delegates here)."""
        prompt = np.asarray(prompt, np.int32)
        if not 1 <= len(prompt) <= self.max_seq - 1:
            # an oversized prompt would clamp its chunk offsets into
            # earlier cache rows and "complete" with scrambled state
            raise ValueError(f"prompt length {len(prompt)} must be in "
                             f"[1, max_seq-1={self.max_seq - 1}]")
        if max_new_tokens < 1:
            # budgets0 = max_new_tokens - 1 would underflow to -1 while the
            # admit path still emits the prefill token — a request asking
            # for 0 tokens used to get 1
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        stop = self.stop_tokens if stop_tokens is None \
            else tuple(int(t) for t in stop_tokens)
        if len(stop) > self.stop_cap:
            # the (S, K) stop matrix is baked into the compiled tick
            raise ValueError(
                f"stop_tokens holds {len(stop)} ids but this engine was "
                f"built with capacity {self.stop_cap} (max(4, "
                f"len(default stop set)))")
        requested = max_new_tokens
        clamped = len(prompt) + max_new_tokens > self.max_seq
        if clamped:
            # the decode loop would stop at the max_seq - 1 ceiling anyway;
            # clamping HERE makes the effective budget visible to paging
            # (no pages reserved for tokens that can never exist) and to
            # the finish_reason ("max_seq", not a silent short "budget")
            max_new_tokens = self.max_seq - len(prompt)
        if self.paged:
            need = self._need_pages(len(prompt), max_new_tokens)
            cap = min(self.num_pages, self.decode_pages)
            if need > cap:
                raise ValueError(
                    f"request needs {need} pages ({len(prompt)} prompt + "
                    f"{max_new_tokens} new tokens at page_size="
                    f"{self.page_size}) but the pool only has {cap}")
        # uid comes from a monotonic counter: queue length would recycle
        # ids once requests drain, aliasing two live requests
        uid = next(self._next_uid)
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      seed=uid if seed is None else int(seed),
                      t_submit=time.perf_counter(),
                      stop_tokens=stop, requested=requested,
                      clamped=clamped)
        if self.prefix is not None:
            # hash every chunk-aligned prefix ONCE, here — admission only
            # compares precomputed keys
            req.prefix_keys = self.prefix.keys_for(prompt)
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    # admission policy
    # ------------------------------------------------------------------

    def plan_round(self) -> AdmissionRound | None:
        """Decide one admission round: FIFO over the queue into free
        admission slots, with the paged bookkeeping (prefix matching,
        LRU eviction, backpressure, mirror grant replay) exactly as the
        pre-split Engine._admit made it.  Returns None when nothing at
        all happened; an AdmissionRound with empty `admitted` means an
        eviction round whose deltas still need the device commit."""
        ns, C = self.num_slots, self.prefill_chunk
        paged = self.paged
        admitted: list[tuple[int, Request]] = []
        # round plan: slot -> (matched_len, shared ids, cow page, fresh)
        plan: dict[int, tuple[int, list, int, int]] = {}
        evict_delta: dict[int, int] = {}
        reg_delta: dict[int, int] = {}
        if paged:
            # phase 1 — FIFO decisions on COUNTS only: `eff` accumulates
            # this round's pending share bumps and eviction decrements so
            # freeness checks see the round's true end state; actual page
            # ids are assigned once, at the end, exactly like the device's
            # single post-evict post-share grant pass
            eff = self.pool.refs.copy()
            free_cnt = int((eff == 0).sum())
        for slot in range(ns):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            if paged:
                if self.prefix is not None:
                    # pure planning — hit/miss telemetry and the LRU tick
                    # are committed below, only once admission succeeds (a
                    # backpressured head re-plans every round and must not
                    # re-count)
                    m_len, full, cow, mkey = self.prefix.match(
                        req.prefix_keys, len(req.prompt))
                else:
                    m_len, full, cow, mkey = 0, [], -1, None
                need = self._need_pages(len(req.prompt), req.max_new_tokens)
                n_fresh = need - len(full)
                # shares first: they may resurrect a cached page whose
                # refcount would otherwise read as free
                for p in full:
                    if eff[p] == 0:
                        free_cnt -= 1
                    eff[p] += 1
                if n_fresh > free_cnt and self.prefix is not None:
                    # pool dry: evict idle cached prefixes (LRU) before
                    # stalling admission
                    free_cnt += self.prefix.evict(n_fresh - free_cnt, eff,
                                                  evict_delta)
                if n_fresh > free_cnt:
                    # still dry: roll this request's shares back and hold
                    # the WHOLE queue (FIFO — skipping the head for a
                    # smaller request behind it would make admission order
                    # depend on pool state)
                    for p in full:
                        eff[p] -= 1
                        if eff[p] == 0:
                            free_cnt += 1
                    break
                free_cnt -= n_fresh
                plan[slot] = (m_len, full, cow, n_fresh)
                if self.prefix is not None:
                    self.prefix.commit(mkey, m_len)
            self.queue.pop(0)
            self.slot_req[slot] = req
            admitted.append((slot, req))
        if not admitted:
            if paged and evict_delta:
                # eviction already dropped chains from the registry; its
                # refcount decrements must land even though the round
                # admits nothing, or the evicted pages' cache refs leak
                # forever (pool reads as occupied, admission wedges, and
                # the I3 identity breaks)
                self.pool.apply_delta(evict_delta)
                return AdmissionRound([], {}, {}, {}, evict_delta, {})
            return None
        if paged:
            # phase 2 — assign page ids (mirrors the device's grant rule:
            # lowest free id first, slots in ascending order) and register
            # the admitted prompts' chains for future rounds.  Same-round
            # self-matching is impossible by construction — a chain only
            # becomes matchable after its producer's prefill ran.
            granted = self.pool.admit_round(
                [(s, plan[s][1], plan[s][3]) for s, _ in admitted],
                evict_delta)
            if self.prefix is not None:
                for slot, req in admitted:
                    self.prefix.register(req.prefix_keys,
                                         plan[slot][1] + granted[slot],
                                         reg_delta)
                self.pool.apply_register(reg_delta)
        starts = {s: plan[s][0] if paged else 0 for s, _ in admitted}
        n_chunks = {s: max(1, -(-(len(r.prompt) - starts[s]) // C))
                    for s, r in admitted}
        skipped = 0
        for slot, req in admitted:
            req.prefill_tokens = len(req.prompt) - starts[slot]
            req.pages_shared = len(plan[slot][1]) if paged else 0
            if paged:
                skipped += max(1, -(-len(req.prompt) // C)) - n_chunks[slot]
        return AdmissionRound(admitted, plan, starts, n_chunks,
                              evict_delta, reg_delta, skipped)

    # ------------------------------------------------------------------
    # disagg transfer policy
    # ------------------------------------------------------------------

    def mark_ready(self, slot: int) -> None:
        """Disagg: the prefill worker finished `slot`'s prompt; queue it
        (FIFO) for the page transfer into the decode pool."""
        req = self.slot_req[slot]
        self.ready.append(req)
        self._ready_slot[req.uid] = slot

    def drop_ready(self, req: Request) -> int:
        """Remove an aborted request from the transfer queue; returns
        the prefill slot it still occupies (the caller releases it)."""
        self.ready.remove(req)
        return self._ready_slot.pop(req.uid)

    def plan_transfers(self) -> list[Transfer]:
        """Decide this round's prefill→decode handoffs, FIFO over the
        ready list.  A transfer needs a free decode slot AND enough free
        decode pages for the request's whole table; when either is dry
        the WHOLE list waits (same FIFO discipline as admission — no
        overtaking), which is the disagg backpressure path: the decode
        tick reclaims pages as requests terminate, un-wedging the head.
        All mirror bookkeeping happens here — destination ids via the
        decode mirror's lowest-free-id grant pass (I7), source release —
        so the device export/import that follows needs no sync."""
        out: list[Transfer] = []
        while self.ready:
            req = self.ready[0]
            src = self._ready_slot[req.uid]
            n = len(self.pool.slot_tables[src])
            dst = next((s for s, r in enumerate(self.decode_slot_req)
                        if r is None), None)
            if dst is None or n > self.decode_pool.free_pages:
                self.transfers_backpressured += 1
                break
            self.ready.pop(0)
            del self._ready_slot[req.uid]
            src_ids = list(self.pool.slot_tables[src])
            granted = self.decode_pool.admit_round([(dst, [], n)], {})
            self.decode_slot_req[dst] = req
            # the device export releases the source refs in the same
            # traced call that gathers the tiles; replay both sides now
            self.pool.release_slot(src)
            self.slot_req[src] = None
            out.append(Transfer(req, src, dst, src_ids, granted[dst], n))
        return out

    # ------------------------------------------------------------------
    # retirement
    # ------------------------------------------------------------------

    def release_admit_slot(self, slot: int) -> None:
        """Retire a request from its ADMISSION-side slot (a first-token
        termination, or a disagg abort before transfer): free the slot,
        replay the device release in the admission mirror, seal it."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        if self.pool is not None:
            self.pool.release_slot(slot)
        self.finish(req)

    def release_decode_slot(self, slot: int) -> None:
        """Retire a request from its DECODE-side slot (the tick's normal
        completion path; identical to release_admit_slot on a colocated
        engine, where the two sides alias)."""
        req = self.decode_slot_req[slot]
        self.decode_slot_req[slot] = None
        if self.decode_pool is not None:
            self.decode_pool.release_slot(slot)
        self.finish(req)

    def finish(self, req: Request) -> None:
        """Seal a completed request: classify the finish reason (highest
        precedence first), build the structured RequestResult and fold
        the request's speculation counters into the engine totals."""
        req.done = True
        out = req.out_tokens
        if req.aborted:
            reason = "aborted"
        elif out and out[-1] in req.stop_tokens:
            reason = "eos"
        elif req.clamped and len(out) >= req.max_new_tokens:
            # the budget was clamped at submit, so exhausting it means the
            # stream ran into the cache ceiling, not the caller's ask
            reason = "max_seq"
        elif len(out) >= req.max_new_tokens:
            reason = "budget"
        else:
            reason = "max_seq"
        self.tokens_drafted += req.drafted_tokens
        self.tokens_accepted += req.accepted_tokens
        req.result = RequestResult(
            uid=req.uid, tokens=tuple(out), finish_reason=reason,
            prefill_tokens=req.prefill_tokens,
            drafted_tokens=req.drafted_tokens,
            accepted_tokens=req.accepted_tokens,
            pages_shared=req.pages_shared,
            ttft=(req.t_first - req.t_submit) if req.t_first else None)
        self.finished.append(req.result)
