"""Self-speculative decoding for the serving engine: draft cheap, verify
exact — the BRAMAC trade (a reduced-precision approximate datapath whose
results are validated by the exact one) applied to token generation.

The drafter here is *self*-speculation: no second model.  Each slot
carries a device-resident direct-mapped n-gram table learned online from
its own prompt + emitted tokens; `propose` chains `draft_len` lookups
from the slot's recent history, the engine scores the whole window
[last_tok, g_1..g_d] in ONE batched forward (the same chunked path
prefill uses), and `sampling.spec_verify` accepts or replaces every
position on device.  A `Drafter` protocol leaves the seam for a future
model-based drafter (e.g. a 2-bit quantized BRAMAC draft model) — the
engine only calls init_state / reset / observe / propose.

Acceptance invariants (the contract the parity suite proves, in the
style of pages.py's I1-I5):

  A1 (greedy parity)  Under greedy sampling the emitted stream is
      bit-identical to non-speculative decoding, whatever the drafter
      proposes: position i's draft is accepted iff it EQUALS the argmax
      of position i-1's verify logits, and the first rejected position
      emits that argmax instead — so every emitted token is exactly the
      token the sequential loop would have produced.  A drafter can only
      change how fast tokens appear, never which tokens.
  A2 (stochastic marginals)  Under temperature/top_k/top_p the accept
      rule is rejection sampling against the drafter's point mass:
      accept g with prob p(g), else resample from p with g masked out —
      each emitted token is marginally ~ p, same as the sequential loop
      (the stream itself may differ: randomness is consumed per window,
      not per token).
  A3 (termination parity)  Stop-token / budget / max_seq clamping is
      applied to the accepted window exactly as the sequential loop
      would: n_emit = min(first-stop-index + 1, n_acc + 1, budget,
      max_seq - 1 - pos), so a request terminates on the same token it
      would have without speculation.
  A4 (rollback)  KV rows written for rejected draft positions
      (window indices >= n_emit) are zeroed through the same
      write-mask/ownership/bound discipline as the original write
      (pages.rollback for the paged pool, rollback_dense here) before
      the tick returns.  Those rows are never attended — the next
      window's queries start at pos + n_emit and overwrite them — but
      rolling them back keeps the cache equal to what a non-speculative
      engine would hold, page-boundary crossings included.
  A5 (determinism)  Table inserts are a sequential scan over observed
      positions (last write wins), never a duplicate-index scatter whose
      XLA ordering is unspecified — the device table bit-matches the
      pure-Python reference replay (tests/test_speculative.py).
  A6 (draft-cache replay)  A model-based drafter's private KV cache
      always equals a fresh replay of the slot's verified stream through
      the draft model: only `observe` writes it (verified emissions,
      appended at the stream offset with the same masked/bounded scatter
      discipline as the main cache), while `propose` threads its
      speculative rows through the scan carry and discards them — a
      rejected window leaves no residue, so the cache "rewinds" to the
      accepted length by construction, tick after tick.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Protocol

import jax
import jax.numpy as jnp

from repro.core import bramac_linear as bl
from repro.models import attention as attn
from repro.models import model as M

# FNV-1a over (token + 1) in wrapping uint32; +1 keeps the -1 history
# padding from colliding with token 0
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619


def ngram_hash(ctx):
    """(… , n) i32 token context -> (…,) u32 hash (FNV-1a, wrapping)."""
    h = jnp.full(ctx.shape[:-1], FNV_OFFSET, jnp.uint32)
    for j in range(ctx.shape[-1]):
        h = (h ^ (ctx[..., j] + 1).astype(jnp.uint32)) \
            * jnp.uint32(FNV_PRIME)
    return h


class DraftState(NamedTuple):
    """Per-slot drafter state, device-resident inside SlotState.

    keys  (S, T) u32 — full context hash stored per bucket (direct-mapped;
          an exact-match check at lookup).  Stored as `hash | 1` so 0
          always means empty: a context whose FNV-1a hash happens to be
          exactly 0 would otherwise false-hit every empty bucket and
          draft token 0
    nexts (S, T) i32 — the token observed after that context
    hist  (S, ctx) i32 — the slot's most recent ctx tokens, -1-padded;
          always ends with the slot's current last_tok"""
    keys: jax.Array
    nexts: jax.Array
    hist: jax.Array


def empty_state(num_slots: int) -> DraftState:
    """Zero-width placeholder keeping SlotState's pytree structure stable
    when speculation is off."""
    return DraftState(jnp.zeros((num_slots, 0), jnp.uint32),
                      jnp.zeros((num_slots, 0), jnp.int32),
                      jnp.zeros((num_slots, 0), jnp.int32))


class Drafter(Protocol):
    """What the engine needs from a drafter.  All methods are traced
    inside the jit'd tick/admit; state must be a fixed-shape pytree.
    Two implementations: `NGramDrafter` (online n-gram table) and
    `QuantDrafter` (the 2-bit BRAMAC draft model — `observe` replays
    verified emissions through a private draft KV cache, `propose` is
    `draft_len` cheap quantized decode steps)."""

    def init_state(self, num_slots: int): ...

    def reset(self, state, mask): ...

    def observe(self, state, tokens, mask): ...

    def propose(self, state, draft_len: int): ...


@dataclasses.dataclass(frozen=True)
class NGramDrafter:
    """Prompt-lookup / n-gram self-speculation.

    A direct-mapped table of `table` buckets per slot maps the hash of
    the last `ngram - 1` tokens to the token that followed it last time
    (last write wins).  Lookups verify the stored full hash; a miss
    falls back to repeating the most recent token — which makes heavily
    repetitive streams (the speculative sweet spot) draftable even
    before their transitions are tabled."""
    ngram: int = 2
    table: int = 512

    @property
    def ctx(self) -> int:
        return self.ngram - 1

    def init_state(self, num_slots: int) -> DraftState:
        return DraftState(
            jnp.zeros((num_slots, self.table), jnp.uint32),
            jnp.zeros((num_slots, self.table), jnp.int32),
            jnp.full((num_slots, self.ctx), -1, jnp.int32))

    def reset(self, ds: DraftState, mask) -> DraftState:
        """Clear the slots in `mask` (S,) bool — a new request must not
        inherit its slot's previous occupant's transitions."""
        m = mask[:, None]
        return DraftState(jnp.where(m, jnp.uint32(0), ds.keys),
                          jnp.where(m, 0, ds.nexts),
                          jnp.where(m, -1, ds.hist))

    def observe(self, ds: DraftState, tokens, mask) -> DraftState:
        """Feed observed tokens (S, L) i32 in window order; mask (S, L)
        bool selects the real entries per slot.  Inserts one transition
        (hash(hist) -> token) per observed token and shifts the history
        — a sequential scan, so same-bucket collisions resolve
        last-write-wins deterministically (invariant A5)."""
        S, T = tokens.shape[0], self.table
        rows = jnp.arange(S)

        def step(st, tm):
            tok, m = tm
            h = ngram_hash(st.hist)                        # (S,)
            idx = (h % T).astype(jnp.int32)
            tgt = jnp.where(m, idx, T)                     # T -> dropped
            # low bit forced to 1: a stored key can never equal the
            # empty-bucket sentinel 0 (lookup applies the same offset)
            keys = st.keys.at[rows, tgt].set(h | jnp.uint32(1), mode="drop")
            nexts = st.nexts.at[rows, tgt].set(tok, mode="drop")
            hist = jnp.where(
                m[:, None],
                jnp.concatenate([st.hist[:, 1:], tok[:, None]], axis=1),
                st.hist)
            return DraftState(keys, nexts, hist), None

        ds, _ = jax.lax.scan(step, ds, (tokens.T, mask.T))
        return ds

    def propose(self, ds: DraftState, draft_len: int):
        """Chain `draft_len` table lookups from each slot's history.
        Read-only: speculative continuations are never inserted (only
        verified emissions are, via observe).  Returns (S, draft_len)
        i32 drafts."""
        S, T = ds.hist.shape[0], self.table
        rows = jnp.arange(S)

        def step(hist, _):
            h = ngram_hash(hist)
            idx = (h % T).astype(jnp.int32)
            hit = ds.keys[rows, idx] == (h | jnp.uint32(1))
            g = jnp.where(hit, ds.nexts[rows, idx], hist[:, -1])
            hist = jnp.concatenate([hist[:, 1:], g[:, None]], axis=1)
            return hist, g

        _, gs = jax.lax.scan(step, ds.hist, None, length=draft_len)
        return gs.T                                        # (S, draft_len)


class QuantDraftState(NamedTuple):
    """Per-slot state of the model-based drafter, riding inside SlotState.

    params    the requantized draft parameter tree.  Carried as state
              (not a jit closure constant) so buffer donation aliases it
              through every tick at zero copies — reset/observe/propose
              all return it untouched.
    caches    private dense draft KV, (n_periods, S, max_seq, …) leaves
              from model.init_cache on the draft config.
    n_stream  (S,) i32 — verified-stream length = draft-cache rows held.
    last      (S,) i32 — the slot's most recent verified token."""
    params: Any
    caches: Any
    n_stream: jax.Array
    last: jax.Array


@dataclasses.dataclass(frozen=True)
class QuantDrafter:
    """The 2-bit BRAMAC draft model: the serving model's own weights
    requantized to `draft_bits` (optionally truncated to the first
    `draft_layers` blocks, sharing embeddings and head), run through the
    quantized serving kernel path (`bramac_linear.serve_dense` →
    `ops.quant_matmul`) — the paper's reduced-precision datapath drafting
    for the exact one.

    The draft KV cache obeys invariant A6: rows [0, n_stream) hold
    exactly the K/V of the slot's verified stream, nothing else.
    `observe` appends a tick's verified emissions in ONE chunked draft
    forward at `cache_pos = n_stream` (masked rows and rows at or past
    the per-slot bound drop, exactly like the main cache's speculative
    write discipline); `propose` decodes `draft_len` greedy steps
    feeding `last` at position n_stream - 1 first — its speculative
    rows live only in the scan carry, so a rejected window needs no
    explicit rewind.  `reset` restores admitted slots' rows to the
    init-cache values (int8-KV scale leaves init to ones, so a zero
    blanket would corrupt the layout)."""
    cfg: Any                       # draft ModelConfig (quant enabled)
    params: Any = dataclasses.field(repr=False)
    max_seq: int = 0

    @classmethod
    def build(cls, cfg, params, max_seq: int, bits: int,
              draft_layers: int | None) -> "QuantDrafter":
        """Requantize the serving tree into a drafter.

        `draft_layers` truncates to the first N blocks (must divide into
        whole periods of cfg.layer_pattern); embeddings and final norm
        are shared with the serving tree, the unembed head is
        requantized like every other servable matmul."""
        n_layers = cfg.num_layers if draft_layers is None else draft_layers
        pat = len(cfg.layer_pattern)
        if n_layers % pat or not 0 < n_layers <= cfg.num_layers:
            raise ValueError(
                f"draft_layers must be a multiple of the {pat}-block "
                f"layer pattern in [1, {cfg.num_layers}], got {n_layers}")
        periods = n_layers // pat
        draft_cfg = cfg.replace(
            num_layers=n_layers,
            quant=bl.QuantConfig(enabled=True, bits_w=bits, bits_a=bits))
        # leading axis of every stacked leaf is the scan period; a
        # QuantizedTensor leaf slices through its values/scale children
        # (its static `shape` goes stale, which unpack never consults)
        layers = jax.tree_util.tree_map(lambda a: a[:periods],
                                        params["layers"])
        draft_params = bl.tree_requantize_serving(
            {"embed": params["embed"], "final_norm": params["final_norm"],
             "layers": layers}, draft_cfg.quant)
        return cls(cfg=draft_cfg, params=draft_params, max_seq=max_seq)

    def init_state(self, num_slots: int) -> QuantDraftState:
        return QuantDraftState(
            params=self.params,
            caches=M.init_cache(self.cfg, num_slots, self.max_seq),
            n_stream=jnp.zeros((num_slots,), jnp.int32),
            last=jnp.zeros((num_slots,), jnp.int32))

    def reset(self, ds: QuantDraftState, mask) -> QuantDraftState:
        """Restore the slots in `mask` (S,) bool to init-cache values
        (NOT zeros — int8-KV scale leaves init to ones)."""
        S = ds.n_stream.shape[0]
        init = M.init_cache(self.cfg, S, self.max_seq)

        def merge(cur, ini):
            m = mask.reshape((1, S) + (1,) * (cur.ndim - 2))
            return jnp.where(m, ini, cur)

        return QuantDraftState(
            params=ds.params,
            caches=jax.tree_util.tree_map(merge, ds.caches, init),
            n_stream=jnp.where(mask, 0, ds.n_stream),
            last=jnp.where(mask, 0, ds.last))

    def observe(self, ds: QuantDraftState, tokens, mask) -> QuantDraftState:
        """Append verified tokens (S, L) i32 to the draft cache in one
        chunked draft forward at cache_pos = n_stream.  mask (S, L) bool
        must be left-contiguous per slot (it is at every call site:
        admission prefill chunks and the tick's emission window); rows
        at or past each slot's n_stream + n bound drop, so the masked
        tail of the chunk can never contaminate the cache (A6)."""
        n = jnp.sum(mask, axis=1).astype(jnp.int32)        # (S,)
        pv = attn.DenseKV(write_mask=n > 0, max_seq=self.max_seq,
                          bound=ds.n_stream + n)
        _, _, caches = M.forward(
            self.params, {"tokens": tokens}, self.cfg, caches=ds.caches,
            cache_pos=ds.n_stream, last_only=True, paged=pv)
        L = tokens.shape[1]
        last = jnp.take_along_axis(
            tokens, jnp.clip(n - 1, 0, L - 1)[:, None], axis=1)[:, 0]
        return QuantDraftState(
            params=ds.params, caches=caches,
            n_stream=ds.n_stream + n,
            last=jnp.where(n > 0, last, ds.last))

    def propose(self, ds: QuantDraftState, draft_len: int):
        """`draft_len` greedy draft decode steps from the verified
        stream.  The first step feeds `last` at position n_stream - 1
        (an identical rewrite of a row the cache already holds); every
        speculative row lives in the scan carry and is discarded with
        it, so the persistent draft cache never sees a draft token (A6).
        Returns (S, draft_len) i32 drafts."""
        S = ds.n_stream.shape[0]
        pv = attn.DenseKV(write_mask=jnp.ones((S,), bool),
                          max_seq=self.max_seq)

        def step(carry, _):
            caches, tok, pos = carry
            logits, caches = M.decode_step(
                self.params, tok[:, None], self.cfg, caches, pos, paged=pv)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (caches, g, pos + 1), g

        _, gs = jax.lax.scan(
            step, (ds.caches, ds.last, ds.n_stream - 1), None,
            length=draft_len)
        return gs.T                                        # (S, draft_len)


def rollback_dense(caches, kv_flags, positions, write_mask, max_seq: int):
    """Zero rejected speculative rows in the dense layout (invariant A4).

    positions (S, L) holds the rejected rows' absolute positions (the
    caller routes kept rows to max_seq, which drops); kv_flags is
    model.cache_pool_flags(cfg) — True exactly at the attention KV
    leaves, whose dense shape is (n_periods, S, max_seq, ...)."""
    ok = write_mask[:, None] & (positions < max_seq)
    pos = jnp.where(ok, positions, max_seq)
    rows = jnp.arange(positions.shape[0])[:, None]

    def zero(leaf, flag):
        if not flag:
            return leaf
        return leaf.at[:, rows, pos].set(0, mode="drop")

    return jax.tree_util.tree_map(zero, caches, kv_flags)
