"""Self-speculative decoding for the serving engine: draft cheap, verify
exact — the BRAMAC trade (a reduced-precision approximate datapath whose
results are validated by the exact one) applied to token generation.

The drafter here is *self*-speculation: no second model.  Each slot
carries a device-resident direct-mapped n-gram table learned online from
its own prompt + emitted tokens; `propose` chains `draft_len` lookups
from the slot's recent history, the engine scores the whole window
[last_tok, g_1..g_d] in ONE batched forward (the same chunked path
prefill uses), and `sampling.spec_verify` accepts or replaces every
position on device.  A `Drafter` protocol leaves the seam for a future
model-based drafter (e.g. a 2-bit quantized BRAMAC draft model) — the
engine only calls init_state / reset / observe / propose.

Acceptance invariants (the contract the parity suite proves, in the
style of pages.py's I1-I5):

  A1 (greedy parity)  Under greedy sampling the emitted stream is
      bit-identical to non-speculative decoding, whatever the drafter
      proposes: position i's draft is accepted iff it EQUALS the argmax
      of position i-1's verify logits, and the first rejected position
      emits that argmax instead — so every emitted token is exactly the
      token the sequential loop would have produced.  A drafter can only
      change how fast tokens appear, never which tokens.
  A2 (stochastic marginals)  Under temperature/top_k/top_p the accept
      rule is rejection sampling against the drafter's point mass:
      accept g with prob p(g), else resample from p with g masked out —
      each emitted token is marginally ~ p, same as the sequential loop
      (the stream itself may differ: randomness is consumed per window,
      not per token).
  A3 (termination parity)  Stop-token / budget / max_seq clamping is
      applied to the accepted window exactly as the sequential loop
      would: n_emit = min(first-stop-index + 1, n_acc + 1, budget,
      max_seq - 1 - pos), so a request terminates on the same token it
      would have without speculation.
  A4 (rollback)  KV rows written for rejected draft positions
      (window indices >= n_emit) are zeroed through the same
      write-mask/ownership/bound discipline as the original write
      (pages.rollback for the paged pool, rollback_dense here) before
      the tick returns.  Those rows are never attended — the next
      window's queries start at pos + n_emit and overwrite them — but
      rolling them back keeps the cache equal to what a non-speculative
      engine would hold, page-boundary crossings included.
  A5 (determinism)  Table inserts are a sequential scan over observed
      positions (last write wins), never a duplicate-index scatter whose
      XLA ordering is unspecified — the device table bit-matches the
      pure-Python reference replay (tests/test_speculative.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol

import jax
import jax.numpy as jnp

# FNV-1a over (token + 1) in wrapping uint32; +1 keeps the -1 history
# padding from colliding with token 0
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619


def ngram_hash(ctx):
    """(… , n) i32 token context -> (…,) u32 hash (FNV-1a, wrapping)."""
    h = jnp.full(ctx.shape[:-1], FNV_OFFSET, jnp.uint32)
    for j in range(ctx.shape[-1]):
        h = (h ^ (ctx[..., j] + 1).astype(jnp.uint32)) \
            * jnp.uint32(FNV_PRIME)
    return h


class DraftState(NamedTuple):
    """Per-slot drafter state, device-resident inside SlotState.

    keys  (S, T) u32 — full context hash stored per bucket (direct-mapped;
          an exact-match check at lookup, 0 means empty)
    nexts (S, T) i32 — the token observed after that context
    hist  (S, ctx) i32 — the slot's most recent ctx tokens, -1-padded;
          always ends with the slot's current last_tok"""
    keys: jax.Array
    nexts: jax.Array
    hist: jax.Array


def empty_state(num_slots: int) -> DraftState:
    """Zero-width placeholder keeping SlotState's pytree structure stable
    when speculation is off."""
    return DraftState(jnp.zeros((num_slots, 0), jnp.uint32),
                      jnp.zeros((num_slots, 0), jnp.int32),
                      jnp.zeros((num_slots, 0), jnp.int32))


class Drafter(Protocol):
    """What the engine needs from a drafter.  All methods are traced
    inside the jit'd tick/admit; state must be a fixed-shape pytree.
    A future model-based drafter (2-bit BRAMAC draft model) plugs in
    here — `observe` would be a no-op and `propose` a forward pass."""

    def init_state(self, num_slots: int): ...

    def reset(self, state, mask): ...

    def observe(self, state, tokens, mask): ...

    def propose(self, state, draft_len: int): ...


@dataclasses.dataclass(frozen=True)
class NGramDrafter:
    """Prompt-lookup / n-gram self-speculation.

    A direct-mapped table of `table` buckets per slot maps the hash of
    the last `ngram - 1` tokens to the token that followed it last time
    (last write wins).  Lookups verify the stored full hash; a miss
    falls back to repeating the most recent token — which makes heavily
    repetitive streams (the speculative sweet spot) draftable even
    before their transitions are tabled."""
    ngram: int = 2
    table: int = 512

    @property
    def ctx(self) -> int:
        return self.ngram - 1

    def init_state(self, num_slots: int) -> DraftState:
        return DraftState(
            jnp.zeros((num_slots, self.table), jnp.uint32),
            jnp.zeros((num_slots, self.table), jnp.int32),
            jnp.full((num_slots, self.ctx), -1, jnp.int32))

    def reset(self, ds: DraftState, mask) -> DraftState:
        """Clear the slots in `mask` (S,) bool — a new request must not
        inherit its slot's previous occupant's transitions."""
        m = mask[:, None]
        return DraftState(jnp.where(m, jnp.uint32(0), ds.keys),
                          jnp.where(m, 0, ds.nexts),
                          jnp.where(m, -1, ds.hist))

    def observe(self, ds: DraftState, tokens, mask) -> DraftState:
        """Feed observed tokens (S, L) i32 in window order; mask (S, L)
        bool selects the real entries per slot.  Inserts one transition
        (hash(hist) -> token) per observed token and shifts the history
        — a sequential scan, so same-bucket collisions resolve
        last-write-wins deterministically (invariant A5)."""
        S, T = tokens.shape[0], self.table
        rows = jnp.arange(S)

        def step(st, tm):
            tok, m = tm
            h = ngram_hash(st.hist)                        # (S,)
            idx = (h % T).astype(jnp.int32)
            tgt = jnp.where(m, idx, T)                     # T -> dropped
            keys = st.keys.at[rows, tgt].set(h, mode="drop")
            nexts = st.nexts.at[rows, tgt].set(tok, mode="drop")
            hist = jnp.where(
                m[:, None],
                jnp.concatenate([st.hist[:, 1:], tok[:, None]], axis=1),
                st.hist)
            return DraftState(keys, nexts, hist), None

        ds, _ = jax.lax.scan(step, ds, (tokens.T, mask.T))
        return ds

    def propose(self, ds: DraftState, draft_len: int):
        """Chain `draft_len` table lookups from each slot's history.
        Read-only: speculative continuations are never inserted (only
        verified emissions are, via observe).  Returns (S, draft_len)
        i32 drafts."""
        S, T = ds.hist.shape[0], self.table
        rows = jnp.arange(S)

        def step(hist, _):
            h = ngram_hash(hist)
            idx = (h % T).astype(jnp.int32)
            hit = ds.keys[rows, idx] == h
            g = jnp.where(hit, ds.nexts[rows, idx], hist[:, -1])
            hist = jnp.concatenate([hist[:, 1:], g[:, None]], axis=1)
            return hist, g

        _, gs = jax.lax.scan(step, ds.hist, None, length=draft_len)
        return gs.T                                        # (S, draft_len)


def rollback_dense(caches, kv_flags, positions, write_mask, max_seq: int):
    """Zero rejected speculative rows in the dense layout (invariant A4).

    positions (S, L) holds the rejected rows' absolute positions (the
    caller routes kept rows to max_seq, which drops); kv_flags is
    model.cache_pool_flags(cfg) — True exactly at the attention KV
    leaves, whose dense shape is (n_periods, S, max_seq, ...)."""
    ok = write_mask[:, None] & (positions < max_seq)
    pos = jnp.where(ok, positions, max_seq)
    rows = jnp.arange(positions.shape[0])[:, None]

    def zero(leaf, flag):
        if not flag:
            return leaf
        return leaf.at[:, rows, pos].set(0, mode="drop")

    return jax.tree_util.tree_map(zero, caches, kv_flags)
