"""Engine configuration and result surface.

`Engine.__init__` grew to 17 loose kwargs over six PRs; this module
groups them into one frozen `EngineOptions` dataclass of themed sections
(sampling, schedule, paging, prefix cache, speculation, parallelism,
disaggregation, debug), each validating itself in `__post_init__` so a bad knob fails at
construction — before anything is traced — with the same error messages
the loose kwargs raised.  `Engine(cfg, params, options=EngineOptions(...))`
is the primary constructor; the legacy flat kwargs are still accepted and
merged via `EngineOptions.build`, so existing callers keep working.

`RequestResult` is the structured completion record the engine attaches
to every finished request (and returns from `Engine.run`): the emitted
tokens, a text-agnostic finish reason, and the serving counters
(prefill compute actually run, speculative drafted/accepted tokens,
prefix pages shared) that previously had to be scraped from engine
telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.runtime.sampling import SamplingConfig

FINISH_REASONS = ("eos", "budget", "max_seq", "aborted")


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class ScheduleOptions:
    """Slot count, sequence ceiling and the fused-loop shapes.

    stop_tokens is the engine-level default stop set (the generalized
    `eos_id`): any emitted token in the set terminates the request; a
    `submit(stop_tokens=...)` override replaces it per request."""
    num_slots: int = 4
    max_seq: int = 128
    decode_steps: int = 1
    prefill_chunk: int = 16
    seed: int = 0
    stop_tokens: tuple = ()

    def __post_init__(self):
        _check(self.num_slots >= 1,
               f"num_slots must be >= 1, got {self.num_slots}")
        _check(self.max_seq >= 2,
               f"max_seq must be >= 2, got {self.max_seq}")
        _check(self.decode_steps >= 1,
               f"decode_steps must be >= 1, got {self.decode_steps}")
        _check(self.prefill_chunk >= 1,
               f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        object.__setattr__(self, "stop_tokens",
                           tuple(int(t) for t in self.stop_tokens))


@dataclasses.dataclass(frozen=True)
class PagingOptions:
    """KV layout: "paged" (shared refcounted page pool) or "dense" (the
    per-slot max_seq reservation kept as the parity oracle).  num_pages
    None means capacity-equal to dense (num_slots * ceil(max_seq /
    page_size)).

    decode_kernel routes the Sq=1 decode read through the pallas
    paged-attention kernel (kernels/paged_attention.py): per-step KV
    traffic walks the block table page by page instead of gathering
    max_seq rows.  None (default) resolves at engine construction to
    "on for a real TPU backend, off elsewhere" — interpret-mode pallas
    inside the fused tick is correct but slow, so CPU runs opt in
    explicitly (as the parity suite and bench_paged do).  gqa layers use
    the kernel; mla and the speculative verify window fall back to the
    gather oracle.  Ignored under kv_layout="dense" and under a mesh
    (the kernel is not partition-annotated)."""
    kv_layout: str = "paged"
    num_pages: int | None = None
    decode_kernel: bool | None = None

    def __post_init__(self):
        if self.kv_layout not in ("paged", "dense"):
            raise ValueError(f"kv_layout must be 'paged' or 'dense', "
                             f"got {self.kv_layout!r}")
        if self.num_pages is not None:
            _check(int(self.num_pages) >= 1,
                   f"num_pages must be >= 1, got {self.num_pages}")


@dataclasses.dataclass(frozen=True)
class PrefixOptions:
    """Copy-on-write prefix caching (paged layout only; recurrent archs
    opt out silently).  chunk None defaults to cfg.page_size."""
    enabled: bool = True
    chunk: int | None = None
    max_chains: int = 4096

    def __post_init__(self):
        if self.chunk is not None:
            _check(int(self.chunk) >= 1,
                   f"prefix chunk must be >= 1, got {self.chunk}")
        _check(self.max_chains >= 1,
               f"prefix_max_chains must be >= 1, got {self.max_chains}")


@dataclasses.dataclass(frozen=True)
class SpeculationOptions:
    """Self-speculative decoding inside the fused tick.

    draft_len 0 disables speculation (the default); > 0 drafts that many
    tokens per decode step from a device-resident per-slot n-gram table
    (`ngram` transition order, `table` direct-mapped buckets) and scores
    them in one batched verify pass.  Greedy streams are bit-identical
    either way — speculation only changes how many host syncs a stream
    costs.  Recurrent-hybrid, cross-attention and MoE archs opt out
    silently (recurrent state cannot rewind a rejected draft; MoE
    capacity drops depend on tokens-per-call, which would break
    verify/decode bit parity).

    `drafter` selects the proposal engine: "ngram" (the table above) or
    "model" — the serving model's own weights requantized to `draft_bits`
    (2 by default: the BRAMAC reduced-precision datapath) and optionally
    truncated to the first `draft_layers` blocks, drafting through a
    private per-slot draft KV cache (speculate.QuantDrafter, invariant
    A6).  The model drafter additionally opts out of the prefix cache:
    a skipped prefill chunk would leave draft-cache rows unwritten.
    """
    draft_len: int = 0
    ngram: int = 2
    table: int = 512
    drafter: str = "ngram"
    draft_bits: int = 2
    draft_layers: int | None = None

    def __post_init__(self):
        _check(self.draft_len >= 0,
               f"draft_len must be >= 0, got {self.draft_len}")
        _check(self.ngram >= 2,
               f"speculation ngram must be >= 2, got {self.ngram}")
        _check(self.table >= 1,
               f"speculation table must be >= 1, got {self.table}")
        if self.drafter not in ("ngram", "model"):
            raise ValueError(f"drafter must be 'ngram' or 'model', "
                             f"got {self.drafter!r}")
        _check(self.draft_bits in (2, 4, 8),
               f"draft_bits must be one of (2, 4, 8), got {self.draft_bits}")
        if self.draft_layers is not None:
            _check(int(self.draft_layers) >= 1,
                   f"draft_layers must be >= 1, got {self.draft_layers}")


@dataclasses.dataclass(frozen=True)
class ParallelOptions:
    """mesh may be a jax Mesh or a build_mesh spec ("model=4", "2x4", 4);
    capacity_factor / dispatch override the MoE routing knobs on cfg for
    this engine (the jit'd functions close over cfg)."""
    mesh: Any = None
    capacity_factor: float | None = None
    dispatch: str | None = None

    def __post_init__(self):
        if self.dispatch is not None and \
                self.dispatch not in ("global", "per_source"):
            raise ValueError(f"dispatch must be 'global' or 'per_source', "
                             f"got {self.dispatch!r}")


@dataclasses.dataclass(frozen=True)
class DisaggOptions:
    """Prefill/decode disaggregation (paged layout only, meshless).

    enabled=True splits the engine into a prefill worker with its OWN
    page pool and slot set and a decode worker owning the fused tick;
    a finished prompt's KV pages move between the pools at page
    granularity (`pages.export_pages` / `import_pages`, invariant I7)
    and greedy streams stay bit-identical to the colocated engine.
    Prefix caching and speculation switch off under disaggregation
    (cached pages would pin the prefill pool the decode side cannot
    read, and drafter state has no page representation to transfer);
    archs with per-slot cache leaves (recurrent hybrids, xattn) are
    rejected for the same reason.

    role="both" runs both workers in this process (the only transport
    implemented today); "prefill" / "decode" name the single-role
    endpoints of the future multi-process transport and currently
    raise NotImplementedError at engine construction.

    prefill_slots / prefill_pages size the prefill worker's slot set
    and pool; None defaults to the decode side's num_slots and a
    capacity-equal pool (prefill_slots * ceil(max_seq / page_size))."""
    enabled: bool = False
    role: str = "both"
    prefill_slots: int | None = None
    prefill_pages: int | None = None

    def __post_init__(self):
        if self.role not in ("prefill", "decode", "both"):
            raise ValueError(f"role must be 'prefill', 'decode' or "
                             f"'both', got {self.role!r}")
        if self.prefill_slots is not None:
            _check(int(self.prefill_slots) >= 1,
                   f"prefill_slots must be >= 1, got {self.prefill_slots}")
        if self.prefill_pages is not None:
            _check(int(self.prefill_pages) >= 1,
                   f"prefill_pages must be >= 1, got {self.prefill_pages}")


@dataclasses.dataclass(frozen=True)
class DebugOptions:
    """check_invariants cross-checks the HostPool mirror against the
    device allocator after every sync (and after speculative rollback
    rounds) — debug aid, costs extra transfers."""
    check_invariants: bool = False


# legacy flat kwarg -> (section attribute, field name)
_LEGACY = {
    "num_slots": ("schedule", "num_slots"),
    "max_seq": ("schedule", "max_seq"),
    "decode_steps": ("schedule", "decode_steps"),
    "prefill_chunk": ("schedule", "prefill_chunk"),
    "seed": ("schedule", "seed"),
    "stop_tokens": ("schedule", "stop_tokens"),
    "kv_layout": ("paging", "kv_layout"),
    "num_pages": ("paging", "num_pages"),
    "decode_kernel": ("paging", "decode_kernel"),
    "prefix_cache": ("prefix", "enabled"),
    "prefix_chunk": ("prefix", "chunk"),
    "prefix_max_chains": ("prefix", "max_chains"),
    "draft_len": ("speculation", "draft_len"),
    "spec_ngram": ("speculation", "ngram"),
    "spec_table": ("speculation", "table"),
    "drafter": ("speculation", "drafter"),
    "draft_bits": ("speculation", "draft_bits"),
    "draft_layers": ("speculation", "draft_layers"),
    "mesh": ("parallel", "mesh"),
    "capacity_factor": ("parallel", "capacity_factor"),
    "dispatch": ("parallel", "dispatch"),
    "disagg": ("disagg", "enabled"),
    "role": ("disagg", "role"),
    "prefill_slots": ("disagg", "prefill_slots"),
    "prefill_pages": ("disagg", "prefill_pages"),
    "check_invariants": ("debug", "check_invariants"),
}


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Everything the serving engine bakes into its compiled functions,
    in one validated bundle.  All sections are frozen: the jit'd tick and
    admit close over these values, so they cannot change after
    construction."""
    sampling: SamplingConfig = SamplingConfig()
    schedule: ScheduleOptions = ScheduleOptions()
    paging: PagingOptions = PagingOptions()
    prefix: PrefixOptions = PrefixOptions()
    speculation: SpeculationOptions = SpeculationOptions()
    parallel: ParallelOptions = ParallelOptions()
    disagg: DisaggOptions = DisaggOptions()
    debug: DebugOptions = DebugOptions()

    def __post_init__(self):
        # ergonomic coercion: EngineOptions(sampling="top_p", ...) would
        # miss the method's parameters, so only the bare method name is
        # accepted here — parameterized methods build a SamplingConfig
        if isinstance(self.sampling, str):
            object.__setattr__(self, "sampling",
                               SamplingConfig(method=self.sampling))
        for name, typ in (("sampling", SamplingConfig),
                          ("schedule", ScheduleOptions),
                          ("paging", PagingOptions),
                          ("prefix", PrefixOptions),
                          ("speculation", SpeculationOptions),
                          ("parallel", ParallelOptions),
                          ("disagg", DisaggOptions),
                          ("debug", DebugOptions)):
            if not isinstance(getattr(self, name), typ):
                raise TypeError(f"EngineOptions.{name} must be a "
                                f"{typ.__name__}, "
                                f"got {type(getattr(self, name)).__name__}")

    @classmethod
    def build(cls, base: "EngineOptions | None" = None,
              **legacy) -> "EngineOptions":
        """Merge flat legacy Engine kwargs over `base` (or the defaults).

        Reproduces the historic loose-kwarg semantics exactly: `sampling`
        may be a method name or a ready SamplingConfig, with
        temperature/top_k/top_p as its parameters; `eos_id` becomes a
        one-token default stop set (an explicit `stop_tokens` wins).
        None values mean "not given" and are skipped; unknown names raise
        TypeError like a bad keyword argument would."""
        base = cls() if base is None else base
        legacy = {k: v for k, v in legacy.items() if v is not None}
        smp_over = {f: legacy.pop(f) for f in
                    ("temperature", "top_k", "top_p") if f in legacy}
        sampling = base.sampling
        if "sampling" in legacy:
            s = legacy.pop("sampling")
            if isinstance(s, SamplingConfig):
                sampling = dataclasses.replace(s, **smp_over) \
                    if smp_over else s
            else:
                knobs = dict(temperature=1.0, top_k=0, top_p=1.0)
                knobs.update(smp_over)
                sampling = SamplingConfig(method=s, **knobs)
        elif smp_over:
            sampling = dataclasses.replace(sampling, **smp_over)
        if "eos_id" in legacy:
            eos = legacy.pop("eos_id")
            legacy.setdefault("stop_tokens", (int(eos),))
        sections: dict[str, dict] = {}
        for name, val in list(legacy.items()):
            if name not in _LEGACY:
                raise TypeError(f"unknown Engine option {name!r}")
            sec, field = _LEGACY[name]
            sections.setdefault(sec, {})[field] = legacy.pop(name)
        out = {"sampling": sampling}
        for sec, over in sections.items():
            out[sec] = dataclasses.replace(getattr(base, sec), **over)
        return dataclasses.replace(base, **out)


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Structured completion record for one request.

    finish_reason (text-agnostic):
      eos     — an emitted token hit the request's stop set
      budget  — the request's max_new_tokens were all emitted
      max_seq — the sequence ceiling bound the request (its budget was
                clamped at submit; see Engine.submit)
      aborted — cancelled via Engine.abort before completing

    Counters:
      prefill_tokens  — prompt tokens whose prefill compute actually ran
                        (prompt length minus the cached-prefix skip)
      drafted_tokens  — speculative tokens proposed for this request
      accepted_tokens — drafted tokens the verify pass emitted (the
                        per-request speedup numerator)
      pages_shared    — prefix-cache pages mapped read-only at admission
      ttft            — wall seconds from submit to first token, or None
                        if the request never produced one."""
    uid: int
    tokens: tuple
    finish_reason: str
    prefill_tokens: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    pages_shared: int = 0
    ttft: float | None = None

    def __post_init__(self):
        if self.finish_reason not in FINISH_REASONS:
            raise ValueError(f"finish_reason must be one of "
                             f"{FINISH_REASONS}, got {self.finish_reason!r}")
        object.__setattr__(self, "tokens",
                           tuple(int(t) for t in self.tokens))
