"""Device-facing worker roles: prefill admission and fused decode.

The engine split (PR 10) pairs this module with `runtime.scheduler`:
the Scheduler decides everything on the host, the workers here compile
and run everything on the device.

  PrefillWorker — owns the chunked admit path: one jit serves every
                  prompt length (fixed `prefill_chunk` chunks, all
                  admitting slots per call), the first chunk of a round
                  carrying the whole pool transaction + copy-on-write
                  split, the final chunk sampling the first token on
                  device.  `run_round` executes a Scheduler
                  AdmissionRound; `export_request` (disagg) gathers a
                  finished prompt's page tiles + slot scalars and
                  releases the source references in the same traced
                  call (I7).

  DecodeWorker  — owns the fused tick (`decode_steps` scanned
                  decode→sample→terminate steps, or the speculative
                  draft→verify→rollback variant) and, in disagg mode,
                  `import_request`: scatter the exported tiles into
                  this pool's granted pages and install the slot state,
                  one compile for every transfer (slot/count are traced
                  scalars).

A colocated Engine points both workers at the SAME state/caches pytree,
which reproduces the pre-split engine exactly; a disaggregated Engine
gives each worker its own pool and moves requests between them at page
granularity.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import model as M
from repro.runtime import pages as pg
from repro.runtime import sampling as smp
from repro.runtime import speculate as spc


class SlotState(NamedTuple):
    """Per-slot decode state; one device-resident pytree for all slots.

    `pages` is the refcounted paged-KV allocator state (empty arrays
    under the dense layout); see `repro.runtime.pages.PagePool`.
    `draft` is the per-slot drafter state (zero-width when speculation
    is off): n-gram tables (`speculate.DraftState`) or the model
    drafter's requantized params + private draft KV cache
    (`speculate.QuantDraftState`)."""
    last_tok: jax.Array     # (S,) i32  last sampled token (next decode input)
    pos: jax.Array          # (S,) i32  next cache index to write
    budget: jax.Array       # (S,) i32  tokens still to emit after this one
    active: jax.Array       # (S,) bool slot is mid-generation
    rng: jax.Array          # (S, 2) u32 per-request sampling key chain
    stop: jax.Array         # (S, K) i32 per-request stop set, -1 padded
    pages: pg.PagePool      # refcounted page allocator (paged layout)
    draft: Any              # drafter state (n-gram tables / draft KV)
    n_drafted: jax.Array    # (S,) i32 drafted tokens, current occupant
    n_accepted: jax.Array   # (S,) i32 drafted tokens emitted


def init_slot_state(num_slots: int, stop_cap: int, table_len: int,
                    num_pages: int, draft) -> SlotState:
    return SlotState(
        last_tok=jnp.zeros((num_slots,), jnp.int32),
        pos=jnp.zeros((num_slots,), jnp.int32),
        budget=jnp.zeros((num_slots,), jnp.int32),
        active=jnp.zeros((num_slots,), bool),
        rng=jnp.zeros((num_slots, 2), jnp.uint32),
        stop=jnp.full((num_slots, stop_cap), -1, jnp.int32),
        pages=pg.init_pool(num_slots, table_len, num_pages),
        draft=draft,
        n_drafted=jnp.zeros((num_slots,), jnp.int32),
        n_accepted=jnp.zeros((num_slots,), jnp.int32))


def _paged_bundle(pool: pg.PagePool, max_seq: int, page_size: int):
    """The PagedKV bundle for one traced call; write_mask is supplied
    by the caller (valid slots at admit, active slots in the tick).
    `owned` routes writes aimed at shared prefix pages to the drop
    index — a slot can never corrupt a page other consumers read.
    `bound` (speculation) additionally drops rows at or past the
    per-slot accepted-length bound.  `kernel` marks the bundle for the
    pallas paged-decode kernel (the Sq=1 tick only — admit chunks and
    the speculative verify window read through the gather oracle)."""
    def bundle(write_mask, bound=None, kernel=False):
        return attn.PagedKV(tables=pool.tables, n_pages=pool.n_pages,
                            write_mask=write_mask, max_seq=max_seq,
                            page_size=page_size, owned=pool.owned,
                            bound=bound, decode_kernel=kernel)
    return bundle


def _donate() -> tuple:
    # buffer donation lets caches/state update in place; the CPU
    # backend doesn't implement donation and would warn on every call
    return () if jax.default_backend() == "cpu" else (1, 2)


class DecodeWorker:
    """Compiles and runs the fused decode tick (and, disaggregated, the
    import half of the page transfer) against ONE pool's state."""

    def __init__(self, *, cfg, num_slots: int, max_seq: int,
                 decode_steps: int, sampling, kv_layout: str,
                 decode_kernel: bool, draft_len: int, drafter,
                 pool_flags, kv_flags):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = cfg.page_size
        self.decode_steps = decode_steps
        self.sampling = sampling
        self.paged = kv_layout == "paged"
        self.decode_kernel = decode_kernel
        self.draft_len = draft_len
        self.drafter = drafter
        self._pool_flags = pool_flags
        self._kv_flags = kv_flags
        tick = self._make_spec_tick() if draft_len else self._make_tick()
        self.tick = jax.jit(tick, donate_argnums=_donate())
        self._import = jax.jit(
            self._make_import(),
            donate_argnums=() if jax.default_backend() == "cpu" else (0, 1)) \
            if self.paged else None

    def _make_tick(self):
        """N fused decode steps: decode -> sample -> terminate, scanned;
        under the paged layout, every reference a slot that terminates
        inside the tick holds is released before the host ever syncs —
        pages reaching refcount zero rejoin the free set."""
        cfg, sc = self.cfg, self.sampling
        max_seq, steps = self.max_seq, self.decode_steps
        page_size = self.page_size
        paged_mode = self.paged
        use_kernel = self.decode_kernel

        def tick(params, state, caches):
            def body(carry, _):
                state, caches = carry
                # inactive slots must not write: their stale block-table
                # entries may point at pages since re-granted to another
                # request (dense slots own their rows, so masking there is
                # unnecessary — and the PR-4 path stays untouched)
                pv = _paged_bundle(state.pages, max_seq, page_size)(
                    state.active, kernel=use_kernel) if paged_mode else None
                logits, caches = M.decode_step(
                    params, state.last_tok[:, None], cfg, caches, state.pos,
                    paged=pv)
                toks, keys = smp.sample(logits, state.rng, sc)
                emit = state.active
                tok = jnp.where(emit, toks, state.last_tok)
                rng = jnp.where(emit[:, None], keys, state.rng)
                pos = jnp.where(emit, state.pos + 1, state.pos)
                budget = jnp.where(emit, state.budget - 1, state.budget)
                # -1-padded stop rows match no real token id
                hit_stop = emit & jnp.any(tok[:, None] == state.stop, axis=1)
                active = emit & (budget > 0) & ~hit_stop & (pos < max_seq - 1)
                new = state._replace(last_tok=tok, pos=pos, budget=budget,
                                     active=active, rng=rng)
                return (new, caches), (tok, emit)

            pre_active = state.active
            (state, caches), (toks, emitted) = jax.lax.scan(
                body, (state, caches), None, length=steps)
            if paged_mode:
                dead = pre_active & ~state.active
                state = state._replace(pages=pg.release(state.pages, dead))
            return state, caches, toks, emitted

        return tick

    def _make_spec_tick(self):
        """The speculative tick: each of the `decode_steps` scanned steps
        drafts `draft_len` tokens from the slot's n-gram table, scores
        the window [last_tok, g_1..g_d] in ONE chunked forward (the same
        path prefill uses — logits[:, i] conditions on the first i
        drafts), accepts/replaces on device (`sampling.spec_verify`) and
        clamps the emission count by stop tokens / budget / max_seq
        exactly as the sequential loop would (invariant A3).  Rejected
        draft rows are rolled back before the step ends (A4).  One host
        sync per tick, however many tokens each window lands."""
        cfg, sc = self.cfg, self.sampling
        max_seq, steps, d = self.max_seq, self.decode_steps, self.draft_len
        L = d + 1
        page_size = self.page_size
        paged_mode = self.paged
        pool_flags, kv_flags = self._pool_flags, self._kv_flags
        drafter = self.drafter

        def tick(params, state, caches):
            def body(carry, _):
                state, caches = carry
                drafts = drafter.propose(state.draft, d)          # (S, d)
                chunk = jnp.concatenate([state.last_tok[:, None], drafts],
                                        axis=1)
                win = state.pos[:, None] \
                    + jnp.arange(L, dtype=jnp.int32)[None]
                # rows a non-speculative run could never reach are dropped
                # at write time (the per-slot accepted-length bound)
                bound = state.pos + state.budget
                if paged_mode:
                    pv = _paged_bundle(state.pages, max_seq, page_size)(
                        state.active, bound)
                else:
                    pv = attn.DenseKV(write_mask=state.active,
                                      max_seq=max_seq, bound=bound)
                logits, _, caches = M.forward(
                    params, {"tokens": chunk}, cfg, caches=caches,
                    cache_pos=state.pos, paged=pv)
                out, n_acc, keys = smp.spec_verify(logits, drafts,
                                                   state.rng, sc)
                idx = jnp.arange(L, dtype=jnp.int32)[None]
                is_stop = jnp.any(out[..., None] == state.stop[:, None, :],
                                  axis=-1)                        # (S, L)
                stop_at = jnp.min(jnp.where(is_stop, idx, L), axis=1)
                # emitted tokens this window: accepted drafts + the
                # model's correction/bonus, clamped exactly as the
                # sequential loop clamps per token (A3); >= 1 for active
                # slots (budget >= 1 and pos < max_seq - 1 while active)
                n_emit = jnp.minimum(
                    jnp.minimum(n_acc + 1, stop_at + 1),
                    jnp.minimum(state.budget, max_seq - 1 - state.pos))
                n_emit = jnp.where(state.active, n_emit, 0)
                emit = idx < n_emit[:, None]                      # (S, L)
                # roll back the rejected rows (window indices >= n_emit)
                rej = jnp.where(emit | ~state.active[:, None], max_seq, win)
                if paged_mode:
                    caches = pg.rollback(caches, pool_flags, pv, rej)
                else:
                    caches = spc.rollback_dense(caches, kv_flags, rej,
                                                state.active, max_seq)
                last = jnp.take_along_axis(
                    out, jnp.clip(n_emit - 1, 0, L - 1)[:, None],
                    axis=1)[:, 0]
                tok = jnp.where(state.active, last, state.last_tok)
                rng = jnp.where(state.active[:, None], keys, state.rng)
                pos = state.pos + n_emit
                budget = state.budget - n_emit
                stopped = jnp.any(is_stop & emit, axis=1)
                active = state.active & ~stopped & (budget > 0) \
                    & (pos < max_seq - 1)
                # the drafter learns only VERIFIED emissions, in order
                ds = drafter.observe(state.draft, out, emit)
                new = state._replace(
                    last_tok=tok, pos=pos, budget=budget, active=active,
                    rng=rng, draft=ds,
                    n_drafted=state.n_drafted
                    + jnp.where(state.active, d, 0),
                    n_accepted=state.n_accepted + jnp.maximum(n_emit - 1, 0))
                return (new, caches), (out, emit)

            pre_active = state.active
            (state, caches), (toks, emitted) = jax.lax.scan(
                body, (state, caches), None, length=steps)
            if paged_mode:
                dead = pre_active & ~state.active
                state = state._replace(pages=pg.release(state.pages, dead))
            return state, caches, toks, emitted

        return tick

    def _make_import(self):
        """The import half of a page transfer (I7): scatter exported
        tiles into this pool's granted pages, adopt them into the slot's
        block table (refcount 1, owned) and install the slot scalars.
        `dst_ids` is (mp,) i32 with entries past `n` ignored; `n` and
        `slot` are traced scalars — one compile serves every transfer."""
        ns = self.num_slots
        pool_flags = self._pool_flags

        def imp(state, caches, tiles, scalars, dst_ids, n, slot):
            mp = state.pages.tables.shape[1]
            live = jnp.arange(mp, dtype=jnp.int32) < n
            caches = pg.import_pages(caches, pool_flags, tiles, dst_ids,
                                     live)
            pool = pg.adopt(state.pages, slot, dst_ids, n)
            onehot = jnp.arange(ns) == slot
            last_tok, pos, budget, rng_row, stop_row = scalars
            state = state._replace(
                last_tok=jnp.where(onehot, last_tok, state.last_tok),
                pos=jnp.where(onehot, pos, state.pos),
                budget=jnp.where(onehot, budget, state.budget),
                active=onehot | state.active,
                rng=jnp.where(onehot[:, None], rng_row[None, :], state.rng),
                stop=jnp.where(onehot[:, None], stop_row[None, :],
                               state.stop),
                pages=pool)
            return state, caches

        return imp

    def import_request(self, state, caches, tiles, scalars, dst_ids,
                       n: int, slot: int):
        return self._import(state, caches, tiles, scalars, dst_ids, n, slot)


class PrefillWorker:
    """Compiles and runs the chunked admission path (and, disaggregated,
    the export half of the page transfer) against ONE pool's state."""

    def __init__(self, *, cfg, num_slots: int, max_seq: int,
                 prefill_chunk: int, stop_cap: int, sampling, base_key,
                 kv_layout: str, pool_flags, draft_len: int, drafter):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = cfg.page_size
        self.pages_per_slot = -(-max_seq // cfg.page_size)
        self.prefill_chunk = prefill_chunk
        self.stop_cap = stop_cap
        self.sampling = sampling
        self.base_key = base_key
        self.paged = kv_layout == "paged"
        self._pool_flags = pool_flags
        self.draft_len = draft_len
        self.drafter = drafter
        self._admit_chunk = jax.jit(self._make_admit_chunk(),
                                    donate_argnums=_donate())
        self._export = jax.jit(
            self._make_export(),
            donate_argnums=() if jax.default_backend() == "cpu" else (0,)) \
            if self.paged else None

    def _make_admit_chunk(self):
        """One prefill chunk for every admitting slot, in one call.

        tokens (S, C) holds each admitting slot's chunk (garbage rows for
        slots mid-decode are masked out of the cache merge); offsets are
        the per-slot chunk starts — a warm-prefix slot's first chunk
        starts at its matched length, not 0.  Rows whose chunk completes
        the prompt (`final`) sample their first token on device and
        commit the slot state; the sampled tokens come back so the host
        can append them.

        Under the paged layout the first chunk of a round also carries
        the round's whole pool transaction, applied via
        `pages.admit_update` in the fixed evict -> share -> grant ->
        register order the HostPool mirror replays, followed by the
        copy-on-write split (`pages.cow_copy`) for slots whose cached
        prefix ends mid-page.  Later chunks pass an all-False `admitting`
        mask and zero deltas — the allocator is a no-op there."""
        cfg, sc = self.cfg, self.sampling
        max_seq, ns = self.max_seq, self.num_slots
        page_size = self.page_size
        base_key = self.base_key
        paged_mode = self.paged
        pool_flags = self._pool_flags
        draft_len, drafter = self.draft_len, self.drafter

        def admit(params, state, caches, tokens, valid, first, offsets,
                  true_lens, seeds, budgets0, stops, admitting, shared,
                  n_shared, new_pages, cow_src, evict_delta, register_delta):
            C = tokens.shape[1]
            if paged_mode:
                pool = pg.admit_update(state.pages, admitting, shared,
                                       n_shared, new_pages, evict_delta,
                                       register_delta)
                state = state._replace(pages=pool)
                # copy-on-write split: a cached prefix that ends mid-page
                # lands as a private copy in the slot's first FRESH page
                # (table entry n_shared — a fresh grant always exists:
                # the matched prefix is capped at prompt_len - 1, so at
                # least the final prompt row needs a writable page).  The
                # copy is traced before any forward write, so it reads
                # the source page's pre-call contents even if its chain
                # was evicted and the page re-granted this same round.
                mp = pool.tables.shape[1]
                dst = jnp.take_along_axis(
                    pool.tables, jnp.clip(n_shared, 0, mp - 1)[:, None],
                    axis=1)[:, 0]
                caches = pg.cow_copy(caches, pool_flags, cow_src, dst)
            # a slot's FIRST chunk starts from pristine state: recurrent
            # mixers accumulate (h/conv/C/n/m carry the previous occupant
            # forward — the seed engine's whole-prompt *_sequence prefill
            # implicitly started from zeros), and KV rows revert to their
            # init values rather than stale garbage (XLA folds the init
            # tree into constants; no second cache is held).  Shared page
            # pools are exempt: co-resident requests own live rows there,
            # and stale rows only ever surface masked to exact zeros.
            # `first` is an explicit host-built mask — warm-prefix slots
            # start their chunk offsets at the matched length, so
            # `offsets == 0` would miss them.

            def reset(cur, ini):
                m = first.reshape((1, ns) + (1,) * (cur.ndim - 2))
                return jnp.where(m, ini.astype(cur.dtype), cur)

            if paged_mode:
                init_tree = M.init_cache(cfg, ns, max_seq,
                                         num_pages=pool.refs.shape[0])
                caches = jax.tree_util.tree_map(
                    lambda cur, ini, pf: cur if pf else reset(cur, ini),
                    caches, init_tree, pool_flags)
            else:
                caches = jax.tree_util.tree_map(
                    reset, caches, M.init_cache(cfg, ns, max_seq))
            # unembed only each slot's true last prompt row (the one whose
            # logits can be sampled), not all C chunk positions
            idx = jnp.clip(true_lens - 1 - offsets, 0, C - 1)
            pv = _paged_bundle(state.pages, max_seq, page_size)(valid) \
                if paged_mode else None
            logits, _, new_caches = M.forward(
                params, {"tokens": tokens}, cfg, caches=caches,
                cache_pos=offsets, gather_pos=idx, paged=pv)

            def merge(old, new):
                m = valid.reshape((1, ns) + (1,) * (old.ndim - 2))
                return jnp.where(m, new.astype(old.dtype), old)

            if paged_mode:
                # pool leaves already masked their writes at scatter time;
                # per-slot leaves (recurrent state, xattn) merge as before
                caches = jax.tree_util.tree_map(
                    lambda old, new, pf: new if pf else merge(old, new),
                    caches, new_caches, pool_flags)
            else:
                caches = jax.tree_util.tree_map(merge, caches, new_caches)
            last = logits[:, 0]                                 # (S, V)
            final = valid & (offsets + C >= true_lens)
            keys0 = smp.request_keys(base_key, seeds)
            toks, keys = smp.sample(last, keys0, sc)
            # per-request stop set; -1 padding matches no real token id
            hit_stop = final & jnp.any(toks[:, None] == stops, axis=1)
            act = final & (budgets0 > 0) & ~hit_stop \
                & (true_lens < max_seq - 1)
            state = state._replace(
                last_tok=jnp.where(final, toks, state.last_tok),
                pos=jnp.where(final, true_lens, state.pos),
                budget=jnp.where(final, budgets0, state.budget),
                active=jnp.where(final, act, state.active),
                rng=jnp.where(final[:, None], keys, state.rng),
                stop=jnp.where(final[:, None], stops, state.stop))
            if draft_len:
                # seed the drafter from the prompt: clear the slot on its
                # first chunk, then observe this chunk's real tokens in
                # order, plus the sampled first token on the final chunk —
                # so tick-time proposals can draft from prompt n-grams
                # (prompt-lookup decoding)
                ds = drafter.reset(state.draft, first)
                cmask = valid[:, None] \
                    & (offsets[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
                       < true_lens[:, None])
                ds = drafter.observe(ds, tokens, cmask)
                ds = drafter.observe(ds, toks[:, None], final[:, None])
                state = state._replace(
                    draft=ds,
                    n_drafted=jnp.where(first, 0, state.n_drafted),
                    n_accepted=jnp.where(first, 0, state.n_accepted))
            if paged_mode:
                # a request that terminates AT admission (first token EOS,
                # or no decode room) must drop its references right here
                dead = final & ~act
                state = state._replace(pages=pg.release(state.pages, dead))
            return state, caches, toks

        return admit

    def _make_export(self):
        """The export half of a page transfer (I7): gather the departing
        slot's page tiles and scalar state, then release its references
        and deactivate it — all in ONE traced call, so the source pool
        can never be observed holding refs for rows already copied out.
        The caches come back untouched (they are not an output), so the
        released pages' stale rows are simply overwritten by the next
        grant's prefill."""
        ns = self.num_slots
        pool_flags = self._pool_flags

        def export(state, caches, src_ids, slot):
            tiles = pg.export_pages(caches, pool_flags, src_ids)
            take = lambda a: jnp.take(a, slot, axis=0)  # noqa: E731
            scalars = (take(state.last_tok), take(state.pos),
                       take(state.budget), take(state.rng),
                       take(state.stop))
            onehot = jnp.arange(ns) == slot
            state = state._replace(
                active=state.active & ~onehot,
                pages=pg.release(state.pages, onehot))
            return state, tiles, scalars

        return export

    def export_request(self, state, caches, src_ids, slot: int):
        return self._export(state, caches, src_ids, slot)

    def run_round(self, params, state, caches, rnd):
        """Execute a Scheduler AdmissionRound: build the per-chunk host
        arrays and drive the compiled admit over every chunk.  Returns
        the updated (state, caches), the per-slot final-chunk token
        arrays (device-resident — the caller owns the sync) and the
        number of compiled calls made."""
        ns, C = self.num_slots, self.prefill_chunk
        paged = self.paged
        admitted, plan = rnd.admitted, rnd.plan
        starts, n_chunks = rnd.starts, rnd.n_chunks
        finals: dict[int, Any] = {}          # slot -> final-chunk tokens
        P = state.pages.refs.shape[0]
        n_calls = 0
        for ci in range(max(n_chunks.values())):
            tokens = np.zeros((ns, C), np.int32)
            valid = np.zeros((ns,), bool)
            offsets = np.zeros((ns,), np.int32)
            true_lens = np.ones((ns,), np.int32)
            seeds = np.zeros((ns,), np.int32)
            budgets0 = np.zeros((ns,), np.int32)
            stops = np.full((ns, self.stop_cap), -1, np.int32)
            admitting = np.zeros((ns,), bool)
            shared = np.zeros((ns, self.pages_per_slot), np.int32)
            n_shared = np.zeros((ns,), np.int32)
            new_pages = np.zeros((ns,), np.int32)
            cow_src = np.full((ns,), -1, np.int32)
            ev_arr = np.zeros((P,), np.int32)
            rg_arr = np.zeros((P,), np.int32)
            if paged and ci == 0:
                for p, d in rnd.evict_delta.items():
                    ev_arr[p] = d
                for p, d in rnd.reg_delta.items():
                    rg_arr[p] = d
            for slot, req in admitted:
                if ci >= n_chunks[slot]:
                    continue
                off = starts[slot] + ci * C
                if paged and ci == 0:
                    m_len, full, cow, n_fresh = plan[slot]
                    admitting[slot] = True
                    shared[slot, :len(full)] = full
                    n_shared[slot] = len(full)
                    new_pages[slot] = n_fresh
                    cow_src[slot] = cow
                if ci == n_chunks[slot] - 1 and not paged:
                    # dense only: a final chunk whose padded end would
                    # cross max_seq slides back inside the cache
                    # (dynamic_update_slice would clamp the write start and
                    # scramble rows); the re-covered rows recompute to
                    # identical values.  The paged scatter drops
                    # out-of-range rows instead, so no slide is needed.
                    off = min(off, max(0, self.max_seq - C))
                piece = req.prompt[off:off + C]
                tokens[slot, :len(piece)] = piece
                valid[slot] = True
                offsets[slot] = off
                true_lens[slot] = len(req.prompt)
                seeds[slot] = req.seed
                budgets0[slot] = req.max_new_tokens - 1
                stops[slot, :len(req.stop_tokens)] = req.stop_tokens
            first = valid if ci == 0 else np.zeros((ns,), bool)
            state, caches, toks = self._admit_chunk(
                params, state, caches, jnp.asarray(tokens),
                jnp.asarray(valid), jnp.asarray(first), jnp.asarray(offsets),
                jnp.asarray(true_lens), jnp.asarray(seeds),
                jnp.asarray(budgets0), jnp.asarray(stops),
                jnp.asarray(admitting), jnp.asarray(shared),
                jnp.asarray(n_shared), jnp.asarray(new_pages),
                jnp.asarray(cow_src), jnp.asarray(ev_arr),
                jnp.asarray(rg_arr))
            n_calls += 1
            for slot, req in admitted:
                if ci == n_chunks[slot] - 1:
                    finals[slot] = toks
            del toks
        return state, caches, finals, n_calls
