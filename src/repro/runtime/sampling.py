"""On-device token sampling for the serving engine.

Sampling runs *inside* the jit'd engine tick (runtime/serve.py) so the
host never sees logits: each slot carries its own PRNG key chain in the
device-resident `SlotState`, and a tick emits tokens directly.  The key
chain is derived from the per-request seed alone (not the slot index), so
a request's stream is reproducible regardless of which slot it lands in
or what else is batched alongside it.

Methods:
  greedy      — argmax; consumes no randomness (keys pass through).
  temperature — softmax sample of logits / temperature.
  top_k       — temperature sample restricted to the k highest logits.
  top_p       — temperature sample restricted to the smallest prefix of
                the sorted distribution with cumulative mass >= top_p
                (the best token is always kept).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

METHODS = ("greedy", "temperature", "top_k", "top_p")


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"sampling method must be one of {METHODS}, "
                             f"got {self.method!r}")
        if self.temperature <= 0.0:
            raise ValueError(f"temperature must be > 0, "
                             f"got {self.temperature}")
        if self.method == "top_k" and self.top_k < 1:
            raise ValueError(f"top_k sampling needs top_k >= 1, "
                             f"got {self.top_k}")
        if self.method == "top_p" and not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def request_keys(base_key, seeds):
    """Per-request starting keys: (B,) i32 seeds -> (B, 2) u32 keys.

    Derived from the request seed only, never the slot index."""
    return jax.vmap(lambda s: jax.random.fold_in(base_key, s))(seeds)


def sample(logits, keys, sc: SamplingConfig):
    """logits (B, V), keys (B, 2) u32 -> (tokens (B,) i32, new_keys).

    Stochastic methods split each row's key once per emitted token;
    greedy returns the keys untouched."""
    if sc.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
    pairs = jax.vmap(jax.random.split)(keys)            # (B, 2, 2)
    new_keys, subs = pairs[:, 0], pairs[:, 1]
    l = logits.astype(jnp.float32) / sc.temperature
    if sc.method == "top_k":
        k = min(sc.top_k, l.shape[-1])
        # rank-based mask: a value threshold (`l >= kth`) would keep EVERY
        # logit tied with the k-th largest, growing the nucleus past k.
        # lax.top_k breaks ties by lowest index, so scattering its indices
        # keeps exactly k tokens
        idx = jax.lax.top_k(l, k)[1]                    # (B, k)
        keep = jax.vmap(lambda m, i: m.at[i].set(True))(
            jnp.zeros(l.shape, bool), idx)
        l = jnp.where(keep, l, -jnp.inf)
    elif sc.method == "top_p":
        srt = jnp.sort(l, axis=-1)[:, ::-1]             # descending
        probs = jax.nn.softmax(srt, axis=-1)
        before = jnp.cumsum(probs, axis=-1) - probs     # mass strictly above
        keep = before < sc.top_p                        # best always kept
        thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1)
        l = jnp.where(l >= thresh[:, None], l, -jnp.inf)
    toks = jax.vmap(jax.random.categorical)(subs, l)
    return toks.astype(jnp.int32), new_keys
