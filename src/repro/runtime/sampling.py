"""On-device token sampling for the serving engine.

Sampling runs *inside* the jit'd engine tick (runtime/serve.py) so the
host never sees logits: each slot carries its own PRNG key chain in the
device-resident `SlotState`, and a tick emits tokens directly.  The key
chain is derived from the per-request seed alone (not the slot index), so
a request's stream is reproducible regardless of which slot it lands in
or what else is batched alongside it.

Methods:
  greedy      — argmax; consumes no randomness (keys pass through).
  temperature — softmax sample of logits / temperature.
  top_k       — temperature sample restricted to the k highest logits.
  top_p       — temperature sample restricted to the smallest prefix of
                the sorted distribution with cumulative mass >= top_p
                (the best token is always kept).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

METHODS = ("greedy", "temperature", "top_k", "top_p")


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"sampling method must be one of {METHODS}, "
                             f"got {self.method!r}")
        if self.temperature <= 0.0:
            raise ValueError(f"temperature must be > 0, "
                             f"got {self.temperature}")
        if self.method == "top_k" and self.top_k < 1:
            raise ValueError(f"top_k sampling needs top_k >= 1, "
                             f"got {self.top_k}")
        if self.method == "top_p" and not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def request_keys(base_key, seeds):
    """Per-request starting keys: (B,) i32 seeds -> (B, 2) u32 keys.

    Derived from the request seed only, never the slot index."""
    return jax.vmap(lambda s: jax.random.fold_in(base_key, s))(seeds)


def _filter_logits(logits, sc: SamplingConfig):
    """Temperature scaling + top_k / top_p restriction of (B, V) rows;
    the f32 result is what the stochastic methods sample from (and what
    the speculative accept rule scores drafts against)."""
    l = logits.astype(jnp.float32) / sc.temperature
    if sc.method == "top_k":
        k = min(sc.top_k, l.shape[-1])
        # rank-based mask: a value threshold (`l >= kth`) would keep EVERY
        # logit tied with the k-th largest, growing the nucleus past k.
        # lax.top_k breaks ties by lowest index, so scattering its indices
        # keeps exactly k tokens
        idx = jax.lax.top_k(l, k)[1]                    # (B, k)
        keep = jax.vmap(lambda m, i: m.at[i].set(True))(
            jnp.zeros(l.shape, bool), idx)
        l = jnp.where(keep, l, -jnp.inf)
    elif sc.method == "top_p":
        srt = jnp.sort(l, axis=-1)[:, ::-1]             # descending
        probs = jax.nn.softmax(srt, axis=-1)
        before = jnp.cumsum(probs, axis=-1) - probs     # mass strictly above
        keep = before < sc.top_p                        # best always kept
        thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1)
        l = jnp.where(l >= thresh[:, None], l, -jnp.inf)
    return l


def sample(logits, keys, sc: SamplingConfig):
    """logits (B, V), keys (B, 2) u32 -> (tokens (B,) i32, new_keys).

    Stochastic methods split each row's key once per emitted token;
    greedy returns the keys untouched."""
    if sc.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
    pairs = jax.vmap(jax.random.split)(keys)            # (B, 2, 2)
    new_keys, subs = pairs[:, 0], pairs[:, 1]
    l = _filter_logits(logits, sc)
    toks = jax.vmap(jax.random.categorical)(subs, l)
    return toks.astype(jnp.int32), new_keys


def spec_verify(logits, drafts, keys, sc: SamplingConfig):
    """Vectorized accept/replace for one speculative draft window.

    logits (B, L, V) scored over the chunk [last_tok, g_1 .. g_d]
    (L = d + 1, so logits[:, i] conditions on the first i drafts);
    drafts (B, d) the proposed tokens; keys (B, 2) u32 per-slot chains.

    Returns (out (B, L) i32, n_acc (B,) i32, new_keys) where out[:, i]
    is the token the stream emits at window index i if it reaches that
    far: accepted drafts verbatim for i < n_acc, the model's own
    replacement at i == n_acc (the rejection correction for i < d, the
    bonus token at i == d).  The caller clamps how many of these are
    actually emitted (stop tokens / budget / max_seq).

    Greedy: a draft is accepted iff it equals the argmax of the previous
    position's logits — so every emitted token is exactly the token
    non-speculative greedy decoding would have produced (the parity
    invariant speculate.py documents).  Consumes no randomness.

    Stochastic: per-position rejection sampling against the drafter's
    point-mass proposal — draft g at position i is accepted with
    probability p_i(g) under the filtered/temperature distribution, and
    a rejection resamples from p_i with g masked out (the renormalized
    residual), so each emitted token is marginally distributed exactly
    as p_i, same as non-speculative sampling.  One split per slot per
    window, then per-position fold_in — acceptance at one position
    cannot perturb the draw at another."""
    B, L, _ = logits.shape
    d = L - 1
    if sc.method == "greedy":
        t = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, L)
        ok = (drafts == t[:, :d]).astype(jnp.int32)
        n_acc = jnp.sum(jnp.cumprod(ok, axis=1), axis=1).astype(jnp.int32)
        return t, n_acc, keys
    pairs = jax.vmap(jax.random.split)(keys)                    # (B, 2, 2)
    new_keys, subs = pairs[:, 0], pairs[:, 1]
    l = _filter_logits(logits.reshape(B * L, -1), sc).reshape(
        B, L, logits.shape[-1])
    probs = jax.nn.softmax(l, axis=-1)
    pkeys = jax.vmap(lambda k: jax.vmap(
        lambda i: jax.random.fold_in(k, i))(jnp.arange(L)))(subs)
    halves = jax.vmap(jax.vmap(jax.random.split))(pkeys)        # (B, L, 2, 2)
    k_u, k_c = halves[:, :, 0], halves[:, :, 1]
    u = jax.vmap(jax.vmap(jax.random.uniform))(k_u)             # (B, L)
    p_draft = jnp.take_along_axis(probs[:, :d], drafts[..., None],
                                  axis=-1)[..., 0]              # (B, d)
    acc = (u[:, :d] < p_draft).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(acc, axis=1), axis=1).astype(jnp.int32)
    # replacement draw at every position: the rejected draft is masked out
    # of its own row (the bonus row at i == d has no draft: -1 matches no
    # vocabulary id, so its draw is the plain filtered categorical)
    pad = jnp.concatenate(
        [drafts, jnp.full((B, 1), -1, jnp.int32)], axis=1)      # (B, L)
    lm = jnp.where(jnp.arange(l.shape[-1])[None, None, :] == pad[..., None],
                   -jnp.inf, l)
    repl = jax.vmap(jax.vmap(jax.random.categorical))(
        k_c, lm).astype(jnp.int32)                              # (B, L)
    out = jnp.where(jnp.arange(L)[None, :] < n_acc[:, None], pad, repl)
    return out, n_acc, new_keys
