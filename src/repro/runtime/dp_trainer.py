"""Explicit data-parallel trainer with int8 gradient compression +
error feedback (shard_map over the `data` axis).

Under pjit the backward all-reduces are implicit and full-precision; this
module is the explicit-collective path for bandwidth-constrained meshes:
per-parameter block-wise int8 quantization before the `psum`, with the
quantization *residual* carried to the next step (error feedback), which
keeps SGD convergence (Karimireddy et al.) while cutting gradient traffic
4× — a distributed-optimization trick the multi-pod config can enable for
the slow pod-to-pod links.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

BLOCK = 256


def _q(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(fp), 1, keepdims=True), 1e-12) / 127.
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq(q, scale, shape, size):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:size].reshape(shape)


def compress_decompress(g, err):
    """One error-feedback round: returns (decompressed g_hat, new_err).

    g_hat = DQ(Q(g + err));  new_err = (g + err) - g_hat.
    """
    corrected = g.astype(jnp.float32) + err
    q, scale = _q(corrected)
    g_hat = _dq(q, scale, g.shape, g.size)
    return g_hat, corrected - g_hat


def make_dp_train_step(loss_fn, mesh: Mesh, axis: str = "data",
                       compress: bool = True):
    """Build a shard_map'd DP step: per-shard grads → (int8+EF) all-reduce.

    loss_fn(params, batch) -> scalar.  params replicated; batch sharded on
    axis 0.  Returns step(params, err_tree, batch) ->
    (grads, new_err_tree, loss)."""

    def per_shard(params, err, batch):
        err = jax.tree_util.tree_map(lambda e: e[0], err)   # drop shard dim
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress:
            qg = jax.tree_util.tree_map(compress_decompress, grads, err)
            g_hat = jax.tree_util.tree_map(
                lambda t: t[0], qg, is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree_util.tree_map(
                lambda t: t[1], qg, is_leaf=lambda x: isinstance(x, tuple))
        else:
            g_hat, new_err = grads, err
        g_sync = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), g_hat)
        loss = jax.lax.pmean(loss, axis)
        new_err = jax.tree_util.tree_map(lambda e: e[None], new_err)
        return g_sync, new_err, loss

    return jax.jit(shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), P(axis), P(axis)),
        out_specs=(P(), P(axis), P()),
        check_vma=False))


def init_error_feedback(params, mesh: Mesh, axis: str = "data"):
    """Per-shard error buffers (sharded over the DP axis — each replica
    keeps its own residual)."""
    n = mesh.shape[axis]

    def zeros(p):
        return jnp.zeros((n,) + p.shape, jnp.float32)

    return jax.tree_util.tree_map(zeros, params)
