"""Device-resident continuous-batching engine (the paper's §VI-D
tiling-based inference mode: quantized weights stay resident, inputs
stream).

The engine owns a fixed pool of `num_slots` sequences sharing one KV
cache, plus a `SlotState` pytree (last token, position, budget, active
mask, per-slot PRNG key, and — in the paged layout — the refcounted
`pages.PagePool`) that lives on device for the engine's lifetime.  The
serving loop is compiled data-flow, not Python control-flow — two jit'd
functions do all the work:

  admit  — chunked prefill: every queued prompt is cut into fixed-size
           chunks (`prefill_chunk`; 1 for recurrent mixers, which cannot
           skip padding in their state) and one compiled function per
           chunk prefills ALL admitting slots at once: full-batch forward
           at per-slot cache offsets, masked merge of the touched slots'
           cache rows, and — on each prompt's final chunk — on-device
           sampling of the first token and the slot-state commit.  No
           per-prompt-length recompiles, no host-side full-cache scatter.
           The first chunk of a round also carries the round's entire
           pool transaction (`pages.admit_update`: evictions, read-only
           prefix shares, fresh grants, registrations) plus the
           copy-on-write page split for prompts that diverge from a
           cached prefix mid-page.

  tick   — fused multi-step decode: `decode_steps` iterations of
           decode -> sample (greedy / temperature / top-k / top-p, keyed
           by the per-request seed) -> EOS + budget + max_seq termination
           masking, rolled into ONE jit via `lax.scan`.  The host syncs
           once per tick — i.e. once per `decode_steps` tokens — and gets
           back the (steps, slots) token block plus emission masks.

KV layouts (`kv_layout=`):

  "paged" (default) — the BRAMAC memory discipline applied to the cache:
           attention KV lives in a shared pool of fixed `cfg.page_size`-row
           pages ("BRAM-array-sized" blocks) addressed through per-slot
           int32 block tables.  ALL pool mutation goes through the
           refcounted allocator in `repro.runtime.pages` — grants at
           admission (lowest free page id first — deterministic),
           refcount-bumped read-only shares for prefix-cache hits,
           release-to-zero reclaim the moment a request terminates inside
           the fused tick (or at admission, for first-token EOS).  When
           the pool runs dry the admitter first evicts idle cached
           prefixes (LRU), then exerts backpressure: queued requests
           wait, FIFO, until a terminating request reclaims enough pages.
           Greedy token streams stay bit-identical to the dense layout
           (masked pool rows contribute exact zeros to the softmax, like
           the dense cache's untouched rows).

  "dense" — the PR-4 layout: every slot reserves `max_seq` KV rows up
           front; kept as the parity oracle and for kernels that want the
           contiguous reservation.

Prefix caching (`prefix_cache=True`, paged layout only): prompts are
hashed at `submit` in fixed `prefix_chunk`-token pieces; admission maps
the longest cached prefix's full pages into the slot's block table
read-only (skipping their prefill compute entirely, so warm-prefix TTFT
drops by ~the shared length) and the slot's own prefill starts at the
matched offset.  A partially covered page is handed over as a private
copy (copy-on-write) instead, so cached pages are never written.
Recurrent-hybrid archs opt out silently (their state accumulates over
every token) but stream identically.

Speculative decoding (`EngineOptions.speculation.draft_len > 0`): each
tick step drafts `draft_len` tokens from the configured drafter —
`drafter="ngram"` (default), a device-resident per-slot n-gram table,
or `drafter="model"`, the serving model's own weights requantized to
`draft_bits` (2-bit BRAMAC datapath) decoding through a private
per-slot draft KV cache that rides inside SlotState
(`runtime/speculate.py`) — scores the whole window [last_tok, g_1..g_d]
in ONE forward through the same chunked path prefill uses, and
accepts/replaces every position on device (`sampling.spec_verify`).
Accepted tokens advance the slot several positions per step; rejected
draft rows are rolled back through the block table (`pages.rollback`,
honouring the same write-mask/ownership/bound discipline as the write)
or the dense scatter (`speculate.rollback_dense`).  Greedy streams are
bit-identical to non-speculative decoding (invariants A1-A6 in
speculate.py); the host still syncs once per tick whatever the
acceptance length.  Recurrent-hybrid, cross-attention and MoE archs opt
out silently (recurrent state cannot rewind; MoE capacity drops depend
on the token count per call, which would break verify/decode bit
parity), and the model drafter additionally opts out of the prefix
cache (a skipped warm-prefix chunk would leave draft-cache rows
unwritten).

Construction: `Engine(cfg, params, options=EngineOptions(...))` is the
primary constructor (`repro.runtime.options`); the historic flat kwargs
are still accepted and merged over `options` via `EngineOptions.build`.
Completed requests carry a structured `options.RequestResult` (tokens,
finish_reason, prefill/speculation/page-sharing counters) in
`Request.result`, and `Engine.run` returns the results completed during
the call.

The Python `Engine` is a thin wrapper holding the request queue and the
`pages.HostPool` mirror of the device allocator; it is also a context
manager so the process-global sharding ctx activated by `mesh=` is
released even when serving raises.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import paged_attention as pk_kernel
from repro.models import attention as attn
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.runtime import pages as pg
from repro.runtime import sampling as smp
from repro.runtime import speculate as spc
from repro.runtime.options import EngineOptions, RequestResult


class SlotState(NamedTuple):
    """Per-slot decode state; one device-resident pytree for all slots.

    `pages` is the refcounted paged-KV allocator state (empty arrays
    under the dense layout); see `repro.runtime.pages.PagePool`.
    `draft` is the per-slot drafter state (zero-width when speculation
    is off): n-gram tables (`speculate.DraftState`) or the model
    drafter's requantized params + private draft KV cache
    (`speculate.QuantDraftState`)."""
    last_tok: jax.Array     # (S,) i32  last sampled token (next decode input)
    pos: jax.Array          # (S,) i32  next cache index to write
    budget: jax.Array       # (S,) i32  tokens still to emit after this one
    active: jax.Array       # (S,) bool slot is mid-generation
    rng: jax.Array          # (S, 2) u32 per-request sampling key chain
    stop: jax.Array         # (S, K) i32 per-request stop set, -1 padded
    pages: pg.PagePool      # refcounted page allocator (paged layout)
    draft: Any              # drafter state (n-gram tables / draft KV)
    n_drafted: jax.Array    # (S,) i32 drafted tokens, current occupant
    n_accepted: jax.Array   # (S,) i32 drafted tokens emitted


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int           # effective budget (clamped to max_seq room)
    seed: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0          # wall time the first token landed (TTFT)
    # prefix-cache keys, hashed once at submit: prefix_keys[i] identifies
    # the (i+1)*prefix_chunk-token prefix of `prompt`
    prefix_keys: tuple = ()
    stop_tokens: tuple = ()       # per-request stop set (engine default or
    #                               the submit(stop_tokens=...) override)
    requested: int = 0            # max_new_tokens as asked (pre-clamp)
    clamped: bool = False         # budget clamped by max_seq at submit
    aborted: bool = False
    prefill_tokens: int = 0       # prompt tokens whose prefill compute ran
    pages_shared: int = 0         # prefix pages mapped read-only at admit
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    result: RequestResult | None = None   # set when the request completes


class Engine:
    """`mesh` switches on the sharded decode path: the inference sharding
    profile (`serve_rules`: weights tensor-parallel over `model`, no FSDP
    all-gathers) is activated for the engine's lifetime and the parameter
    tree — float or pre-quantized `QuantizedTensor` leaves alike — is
    placed onto the mesh, so every jit'd prefill/decode below runs
    tensor-parallel.

    Sampling and scheduling knobs (all baked into the compiled functions,
    so they must be set at construction):
      sampling      — "greedy" | "temperature" | "top_k" | "top_p", or a
                      ready-made `sampling.SamplingConfig`
      temperature / top_k / top_p — parameters of the stochastic methods
      decode_steps  — decode steps fused per tick (host syncs per
                      generated token scale as 1/decode_steps)
      prefill_chunk — prompt chunk size for admission (forced to 1 on
                      recurrent mixers); one jit serves every length
      seed          — engine base seed; a request's stream is keyed by
                      fold_in(base, request.seed) only, so it reproduces
                      across slots and co-batched traffic
      stop_tokens   — default per-request stop set; `eos_id=N` is legacy
                      shorthand for stop_tokens=(N,), and submit's
                      stop_tokens= overrides per request
      draft_len     — speculative draft window per decode step; 0 (the
                      default) disables speculation entirely
      spec_ngram / spec_table — n-gram order and per-slot table buckets
                      of the self-speculation drafter (speculate.py)
      drafter       — "ngram" (default) or "model": the 2-bit BRAMAC
                      draft model (the serving weights requantized to
                      draft_bits, optionally truncated to draft_layers
                      blocks) proposing through a private draft KV cache
      kv_layout     — "paged" (default) or "dense" (see module docstring)
      num_pages     — paged pool size; default num_slots * ceil(max_seq /
                      cfg.page_size) (capacity-equal to dense — shrink it
                      to trade co-residency for memory)
      prefix_cache  — share cached prompt prefixes across requests
                      (paged layout only; recurrent mixers opt out)
      prefix_chunk  — prefix hash granularity in tokens (default
                      cfg.page_size; smaller values trade more
                      copy-on-write splits for finer matching)
      prefix_max_chains — registry capacity: LRU chains beyond this are
                      evicted at registration time, bounding host memory
                      under high-cardinality traffic (default 4096)
      check_invariants — verify the HostPool mirror against the device
                      allocator (refcounts, free popcount, block tables)
                      after every sync; debug aid, costs extra transfers
    """

    def __init__(self, cfg, params, num_slots: int | None = None,
                 max_seq: int | None = None, *,
                 options: EngineOptions | None = None, **legacy):
        # `options` is the primary constructor surface; any flat legacy
        # kwargs (including the positional num_slots/max_seq) are merged
        # over it by EngineOptions.build, which owns all validation.
        if num_slots is not None:
            legacy["num_slots"] = num_slots
        if max_seq is not None:
            legacy["max_seq"] = max_seq
        options = EngineOptions.build(base=options, **legacy)
        self.options = options
        sch, par = options.schedule, options.parallel
        num_slots, max_seq = sch.num_slots, sch.max_seq
        # capacity_factor / dispatch override the MoE routing knobs on cfg
        # (moe_capacity_factor / ep_dispatch) for this engine — the jit'd
        # prefill/decode close over cfg, so the override must happen here,
        # before any tracing.
        if par.dispatch is not None:
            cfg = cfg.replace(ep_dispatch=par.dispatch)
        if par.capacity_factor is not None:
            cfg = cfg.replace(moe_capacity_factor=float(par.capacity_factor))
        mesh = par.mesh
        if mesh is not None and not isinstance(mesh, jax.sharding.Mesh):
            mesh = shd.build_mesh(mesh)
        self.mesh = mesh
        self._ctx = None
        if mesh is not None:
            self._ctx = shd.activate(mesh,
                                     shd.serve_rules("pod" in mesh.axis_names))
            params = jax.device_put(params,
                                    shd.param_shardings(params, self._ctx))
        self.cfg, self.params = cfg, params
        self.num_slots, self.max_seq = num_slots, max_seq
        self.stop_tokens = sch.stop_tokens
        # legacy attr: the single-token stop set older callers passed
        self.eos_id = sch.stop_tokens[0] if len(sch.stop_tokens) == 1 \
            else None
        self.sampling = options.sampling
        self.decode_steps = sch.decode_steps
        self.check_invariants = options.debug.check_invariants
        # recurrent mixers (mamba/mlstm/slstm) can't skip padding in their
        # state, so their prompts are fed token-by-token (chunk = 1); a
        # chunk can never exceed the cache (its write must fit max_seq)
        recurrent = any(m in spec for spec in cfg.layer_pattern
                        for m in ("mamba", "mlstm", "slstm"))
        self.prefill_chunk = 1 if recurrent \
            else max(1, min(sch.prefill_chunk, max_seq - 1))
        # --- speculation (silent opt-outs: recurrent state cannot rewind
        # a rejected draft; xattn decode needs vision inputs; MoE capacity
        # drops depend on tokens-per-call, breaking verify/decode parity)
        spec_ok = not recurrent \
            and not any("xattn" in s or "moe" in s
                        for s in cfg.layer_pattern)
        self.draft_len = min(options.speculation.draft_len,
                             max(0, max_seq - 2)) if spec_ok else 0
        self.drafter_kind = options.speculation.drafter \
            if self.draft_len else None
        if not self.draft_len:
            self.drafter = None
        elif self.drafter_kind == "model":
            # the 2-bit BRAMAC draft model: the engine's own weights
            # requantized, with a private per-slot draft KV cache riding
            # inside SlotState (speculate.QuantDrafter, invariant A6)
            self.drafter = spc.QuantDrafter.build(
                cfg, params, max_seq,
                bits=options.speculation.draft_bits,
                draft_layers=options.speculation.draft_layers)
        else:
            self.drafter = spc.NGramDrafter(options.speculation.ngram,
                                            options.speculation.table)
        self._stop_cap = max(4, len(self.stop_tokens))
        self._next_uid = itertools.count()
        self._base_key = jax.random.PRNGKey(sch.seed)
        # --- KV layout ---
        self.kv_layout = options.paging.kv_layout
        self.page_size = cfg.page_size
        self.pages_per_slot = -(-max_seq // self.page_size)  # table length
        if self.kv_layout == "paged":
            self.num_pages = int(options.paging.num_pages) \
                if options.paging.num_pages is not None \
                else num_slots * self.pages_per_slot
            self.caches = M.init_cache(cfg, num_slots, max_seq,
                                       num_pages=self.num_pages)
            self._pool_flags = M.cache_pool_flags(cfg)
            mp, P = self.pages_per_slot, self.num_pages
            self.pool: pg.HostPool | None = pg.HostPool(self.num_pages,
                                                        num_slots)
        else:
            self.num_pages = 0
            self.caches = M.init_cache(cfg, num_slots, max_seq)
            self._pool_flags = None
            mp, P = 0, 0
            self.pool = None
        # dense speculative rollback routes through the KV leaf flags
        # (same tree structure as the paged pool flags)
        self._kv_flags = M.cache_pool_flags(cfg) \
            if self.draft_len and self.kv_layout == "dense" else None
        # --- pallas decode kernel (Sq=1 paged reads walk the block table
        # page by page; None = auto: real TPU only — interpret mode on CPU
        # is correct but slow, so CPU callers opt in).  The speculative
        # tick verifies Sq=draft+1 windows and keeps the gather oracle, as
        # does any mesh-sharded engine (the kernel carries no partition
        # annotations).
        dk = options.paging.decode_kernel
        self.decode_kernel = bool(
            self.kv_layout == "paged" and mesh is None
            and not self.draft_len
            and (dk if dk is not None
                 else jax.default_backend() == "tpu"))
        # --- prefix cache (paged only; recurrent state accumulates over
        # every token, so those archs cannot share prefixes — they opt out
        # silently but stream identically.  The model drafter opts out
        # too: a warm-prefix chunk skips its prefill compute, which would
        # leave the corresponding DRAFT-cache rows unwritten and break
        # invariant A6 — streams stay bit-identical, admission just runs
        # the full prefill) ---
        self.prefix_chunk = int(options.prefix.chunk) \
            if options.prefix.chunk is not None else self.page_size
        enabled = options.prefix.enabled and self.kv_layout == "paged" \
            and not recurrent and self.drafter_kind != "model"
        self.prefix = pg.PrefixCache(self.prefix_chunk, self.page_size,
                                     max_chains=options.prefix.max_chains) \
            if enabled else None
        self.state = SlotState(
            last_tok=jnp.zeros((num_slots,), jnp.int32),
            pos=jnp.zeros((num_slots,), jnp.int32),
            budget=jnp.zeros((num_slots,), jnp.int32),
            active=jnp.zeros((num_slots,), bool),
            rng=jnp.zeros((num_slots, 2), jnp.uint32),
            stop=jnp.full((num_slots, self._stop_cap), -1, jnp.int32),
            pages=pg.init_pool(num_slots, mp, P),
            draft=self.drafter.init_state(num_slots) if self.draft_len
            else spc.empty_state(num_slots),
            n_drafted=jnp.zeros((num_slots,), jnp.int32),
            n_accepted=jnp.zeros((num_slots,), jnp.int32))
        self.slot_req: list[Request | None] = [None] * num_slots
        self._queue: list[Request] = []
        self._finished: list[RequestResult] = []
        # pool-occupancy telemetry; occupancy itself lives in the HostPool
        # mirror (`pages_in_use` property), kept in lockstep with the
        # device allocator so backpressure never needs an extra sync
        self.pages_high_water = 0
        self.pages_shared_high_water = 0
        self.prefill_chunks_skipped = 0
        # host<->device sync accounting for the serving bench: one sync per
        # jit'd tick / per admission round, regardless of decode_steps
        self.n_ticks = 0
        self.n_admit_calls = 0
        self.n_syncs = 0
        self.n_generated = 0
        # decode KV read accounting (kernels/paged_attention currency):
        # bytes the decode path reads from the KV cache, accumulated per
        # tick from the tick-start slot lengths (allocation is fixed
        # within a tick, so this undercounts each slot by at most one
        # page over the tick — deterministic given the same schedule).
        self.kv_bytes_read = 0
        self.kv_read_steps = 0
        self._kv_row_bytes = pk_kernel.kv_row_bytes(cfg)
        # engine-lifetime speculation totals (folded in as requests retire)
        self.tokens_drafted = 0
        self.tokens_accepted = 0
        # buffer donation lets caches/state update in place; the CPU
        # backend doesn't implement donation and would warn on every call
        donate = () if jax.default_backend() == "cpu" else (1, 2)
        tick = self._make_spec_tick() if self.draft_len else self._make_tick()
        self._tick = jax.jit(tick, donate_argnums=donate)
        self._admit_chunk = jax.jit(self._make_admit_chunk(),
                                    donate_argnums=donate)

    # ------------------------------------------------------------------
    # compiled data-flow
    # ------------------------------------------------------------------

    def _paged_kv(self, pool: pg.PagePool):
        """The PagedKV bundle for one traced call; write_mask is supplied
        by the caller (valid slots at admit, active slots in the tick).
        `owned` routes writes aimed at shared prefix pages to the drop
        index — a slot can never corrupt a page other consumers read.
        `bound` (speculation) additionally drops rows at or past the
        per-slot accepted-length bound.  `kernel` marks the bundle for the
        pallas paged-decode kernel (the Sq=1 tick only — admit chunks and
        the speculative verify window read through the gather oracle)."""
        def bundle(write_mask, bound=None, kernel=False):
            return attn.PagedKV(tables=pool.tables, n_pages=pool.n_pages,
                                write_mask=write_mask, max_seq=self.max_seq,
                                page_size=self.page_size, owned=pool.owned,
                                bound=bound, decode_kernel=kernel)
        return bundle

    def _make_tick(self):
        """N fused decode steps: decode -> sample -> terminate, scanned;
        under the paged layout, every reference a slot that terminates
        inside the tick holds is released before the host ever syncs —
        pages reaching refcount zero rejoin the free set."""
        cfg, sc = self.cfg, self.sampling
        max_seq, steps = self.max_seq, self.decode_steps
        paged_mode = self.kv_layout == "paged"
        use_kernel = self.decode_kernel

        def tick(params, state, caches):
            def body(carry, _):
                state, caches = carry
                # inactive slots must not write: their stale block-table
                # entries may point at pages since re-granted to another
                # request (dense slots own their rows, so masking there is
                # unnecessary — and the PR-4 path stays untouched)
                pv = self._paged_kv(state.pages)(state.active,
                                                 kernel=use_kernel) \
                    if paged_mode else None
                logits, caches = M.decode_step(
                    params, state.last_tok[:, None], cfg, caches, state.pos,
                    paged=pv)
                toks, keys = smp.sample(logits, state.rng, sc)
                emit = state.active
                tok = jnp.where(emit, toks, state.last_tok)
                rng = jnp.where(emit[:, None], keys, state.rng)
                pos = jnp.where(emit, state.pos + 1, state.pos)
                budget = jnp.where(emit, state.budget - 1, state.budget)
                # -1-padded stop rows match no real token id
                hit_stop = emit & jnp.any(tok[:, None] == state.stop, axis=1)
                active = emit & (budget > 0) & ~hit_stop & (pos < max_seq - 1)
                new = state._replace(last_tok=tok, pos=pos, budget=budget,
                                     active=active, rng=rng)
                return (new, caches), (tok, emit)

            pre_active = state.active
            (state, caches), (toks, emitted) = jax.lax.scan(
                body, (state, caches), None, length=steps)
            if paged_mode:
                dead = pre_active & ~state.active
                state = state._replace(pages=pg.release(state.pages, dead))
            return state, caches, toks, emitted

        return tick

    def _make_spec_tick(self):
        """The speculative tick: each of the `decode_steps` scanned steps
        drafts `draft_len` tokens from the slot's n-gram table, scores
        the window [last_tok, g_1..g_d] in ONE chunked forward (the same
        path prefill uses — logits[:, i] conditions on the first i
        drafts), accepts/replaces on device (`sampling.spec_verify`) and
        clamps the emission count by stop tokens / budget / max_seq
        exactly as the sequential loop would (invariant A3).  Rejected
        draft rows are rolled back before the step ends (A4).  One host
        sync per tick, however many tokens each window lands."""
        cfg, sc = self.cfg, self.sampling
        max_seq, steps, d = self.max_seq, self.decode_steps, self.draft_len
        L = d + 1
        paged_mode = self.kv_layout == "paged"
        pool_flags, kv_flags = self._pool_flags, self._kv_flags
        drafter = self.drafter

        def tick(params, state, caches):
            def body(carry, _):
                state, caches = carry
                drafts = drafter.propose(state.draft, d)          # (S, d)
                chunk = jnp.concatenate([state.last_tok[:, None], drafts],
                                        axis=1)
                win = state.pos[:, None] \
                    + jnp.arange(L, dtype=jnp.int32)[None]
                # rows a non-speculative run could never reach are dropped
                # at write time (the per-slot accepted-length bound)
                bound = state.pos + state.budget
                if paged_mode:
                    pv = self._paged_kv(state.pages)(state.active, bound)
                else:
                    pv = attn.DenseKV(write_mask=state.active,
                                      max_seq=max_seq, bound=bound)
                logits, _, caches = M.forward(
                    params, {"tokens": chunk}, cfg, caches=caches,
                    cache_pos=state.pos, paged=pv)
                out, n_acc, keys = smp.spec_verify(logits, drafts,
                                                   state.rng, sc)
                idx = jnp.arange(L, dtype=jnp.int32)[None]
                is_stop = jnp.any(out[..., None] == state.stop[:, None, :],
                                  axis=-1)                        # (S, L)
                stop_at = jnp.min(jnp.where(is_stop, idx, L), axis=1)
                # emitted tokens this window: accepted drafts + the
                # model's correction/bonus, clamped exactly as the
                # sequential loop clamps per token (A3); >= 1 for active
                # slots (budget >= 1 and pos < max_seq - 1 while active)
                n_emit = jnp.minimum(
                    jnp.minimum(n_acc + 1, stop_at + 1),
                    jnp.minimum(state.budget, max_seq - 1 - state.pos))
                n_emit = jnp.where(state.active, n_emit, 0)
                emit = idx < n_emit[:, None]                      # (S, L)
                # roll back the rejected rows (window indices >= n_emit)
                rej = jnp.where(emit | ~state.active[:, None], max_seq, win)
                if paged_mode:
                    caches = pg.rollback(caches, pool_flags, pv, rej)
                else:
                    caches = spc.rollback_dense(caches, kv_flags, rej,
                                                state.active, max_seq)
                last = jnp.take_along_axis(
                    out, jnp.clip(n_emit - 1, 0, L - 1)[:, None],
                    axis=1)[:, 0]
                tok = jnp.where(state.active, last, state.last_tok)
                rng = jnp.where(state.active[:, None], keys, state.rng)
                pos = state.pos + n_emit
                budget = state.budget - n_emit
                stopped = jnp.any(is_stop & emit, axis=1)
                active = state.active & ~stopped & (budget > 0) \
                    & (pos < max_seq - 1)
                # the drafter learns only VERIFIED emissions, in order
                ds = drafter.observe(state.draft, out, emit)
                new = state._replace(
                    last_tok=tok, pos=pos, budget=budget, active=active,
                    rng=rng, draft=ds,
                    n_drafted=state.n_drafted
                    + jnp.where(state.active, d, 0),
                    n_accepted=state.n_accepted + jnp.maximum(n_emit - 1, 0))
                return (new, caches), (out, emit)

            pre_active = state.active
            (state, caches), (toks, emitted) = jax.lax.scan(
                body, (state, caches), None, length=steps)
            if paged_mode:
                dead = pre_active & ~state.active
                state = state._replace(pages=pg.release(state.pages, dead))
            return state, caches, toks, emitted

        return tick

    def _make_admit_chunk(self):
        """One prefill chunk for every admitting slot, in one call.

        tokens (S, C) holds each admitting slot's chunk (garbage rows for
        slots mid-decode are masked out of the cache merge); offsets are
        the per-slot chunk starts — a warm-prefix slot's first chunk
        starts at its matched length, not 0.  Rows whose chunk completes
        the prompt (`final`) sample their first token on device and
        commit the slot state; the sampled tokens come back so the host
        can append them.

        Under the paged layout the first chunk of a round also carries
        the round's whole pool transaction, applied via
        `pages.admit_update` in the fixed evict -> share -> grant ->
        register order the HostPool mirror replays, followed by the
        copy-on-write split (`pages.cow_copy`) for slots whose cached
        prefix ends mid-page.  Later chunks pass an all-False `admitting`
        mask and zero deltas — the allocator is a no-op there."""
        cfg, sc = self.cfg, self.sampling
        max_seq, ns = self.max_seq, self.num_slots
        base_key = self._base_key
        paged_mode = self.kv_layout == "paged"
        pool_flags = self._pool_flags
        draft_len, drafter = self.draft_len, self.drafter

        def admit(params, state, caches, tokens, valid, first, offsets,
                  true_lens, seeds, budgets0, stops, admitting, shared,
                  n_shared, new_pages, cow_src, evict_delta, register_delta):
            C = tokens.shape[1]
            if paged_mode:
                pool = pg.admit_update(state.pages, admitting, shared,
                                       n_shared, new_pages, evict_delta,
                                       register_delta)
                state = state._replace(pages=pool)
                # copy-on-write split: a cached prefix that ends mid-page
                # lands as a private copy in the slot's first FRESH page
                # (table entry n_shared — a fresh grant always exists:
                # the matched prefix is capped at prompt_len - 1, so at
                # least the final prompt row needs a writable page).  The
                # copy is traced before any forward write, so it reads
                # the source page's pre-call contents even if its chain
                # was evicted and the page re-granted this same round.
                mp = pool.tables.shape[1]
                dst = jnp.take_along_axis(
                    pool.tables, jnp.clip(n_shared, 0, mp - 1)[:, None],
                    axis=1)[:, 0]
                caches = pg.cow_copy(caches, pool_flags, cow_src, dst)
            # a slot's FIRST chunk starts from pristine state: recurrent
            # mixers accumulate (h/conv/C/n/m carry the previous occupant
            # forward — the seed engine's whole-prompt *_sequence prefill
            # implicitly started from zeros), and KV rows revert to their
            # init values rather than stale garbage (XLA folds the init
            # tree into constants; no second cache is held).  Shared page
            # pools are exempt: co-resident requests own live rows there,
            # and stale rows only ever surface masked to exact zeros.
            # `first` is an explicit host-built mask — warm-prefix slots
            # start their chunk offsets at the matched length, so
            # `offsets == 0` would miss them.

            def reset(cur, ini):
                m = first.reshape((1, ns) + (1,) * (cur.ndim - 2))
                return jnp.where(m, ini.astype(cur.dtype), cur)

            if paged_mode:
                init_tree = M.init_cache(cfg, ns, max_seq,
                                         num_pages=pool.refs.shape[0])
                caches = jax.tree_util.tree_map(
                    lambda cur, ini, pf: cur if pf else reset(cur, ini),
                    caches, init_tree, pool_flags)
            else:
                caches = jax.tree_util.tree_map(
                    reset, caches, M.init_cache(cfg, ns, max_seq))
            # unembed only each slot's true last prompt row (the one whose
            # logits can be sampled), not all C chunk positions
            idx = jnp.clip(true_lens - 1 - offsets, 0, C - 1)
            pv = self._paged_kv(state.pages)(valid) if paged_mode else None
            logits, _, new_caches = M.forward(
                params, {"tokens": tokens}, cfg, caches=caches,
                cache_pos=offsets, gather_pos=idx, paged=pv)

            def merge(old, new):
                m = valid.reshape((1, ns) + (1,) * (old.ndim - 2))
                return jnp.where(m, new.astype(old.dtype), old)

            if paged_mode:
                # pool leaves already masked their writes at scatter time;
                # per-slot leaves (recurrent state, xattn) merge as before
                caches = jax.tree_util.tree_map(
                    lambda old, new, pf: new if pf else merge(old, new),
                    caches, new_caches, pool_flags)
            else:
                caches = jax.tree_util.tree_map(merge, caches, new_caches)
            last = logits[:, 0]                                 # (S, V)
            final = valid & (offsets + C >= true_lens)
            keys0 = smp.request_keys(base_key, seeds)
            toks, keys = smp.sample(last, keys0, sc)
            # per-request stop set; -1 padding matches no real token id
            hit_stop = final & jnp.any(toks[:, None] == stops, axis=1)
            act = final & (budgets0 > 0) & ~hit_stop \
                & (true_lens < max_seq - 1)
            state = state._replace(
                last_tok=jnp.where(final, toks, state.last_tok),
                pos=jnp.where(final, true_lens, state.pos),
                budget=jnp.where(final, budgets0, state.budget),
                active=jnp.where(final, act, state.active),
                rng=jnp.where(final[:, None], keys, state.rng),
                stop=jnp.where(final[:, None], stops, state.stop))
            if draft_len:
                # seed the drafter from the prompt: clear the slot on its
                # first chunk, then observe this chunk's real tokens in
                # order, plus the sampled first token on the final chunk —
                # so tick-time proposals can draft from prompt n-grams
                # (prompt-lookup decoding)
                ds = drafter.reset(state.draft, first)
                cmask = valid[:, None] \
                    & (offsets[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
                       < true_lens[:, None])
                ds = drafter.observe(ds, tokens, cmask)
                ds = drafter.observe(ds, toks[:, None], final[:, None])
                state = state._replace(
                    draft=ds,
                    n_drafted=jnp.where(first, 0, state.n_drafted),
                    n_accepted=jnp.where(first, 0, state.n_accepted))
            if paged_mode:
                # a request that terminates AT admission (first token EOS,
                # or no decode room) must drop its references right here
                dead = final & ~act
                state = state._replace(pages=pg.release(state.pages, dead))
            return state, caches, toks

        return admit

    # ------------------------------------------------------------------
    # host-side request plumbing
    # ------------------------------------------------------------------

    def _need_pages(self, prompt_len: int, max_new: int) -> int:
        """Pages a request occupies for its whole lifetime: prompt rows
        plus one KV row per decode step (the first token comes from the
        prefill logits), clipped to the max_seq-1 generation ceiling."""
        rows = min(prompt_len + max_new - 1, self.max_seq - 1)
        return -(-rows // self.page_size)

    def submit(self, prompt, max_new_tokens: int = 16,
               seed: int | None = None,
               stop_tokens: tuple | None = None) -> Request:
        """Queue a prompt.  `stop_tokens` overrides the engine's default
        stop set for this request (any emitted token in the set ends the
        stream, finish_reason="eos").  A budget that cannot fit the cache
        is clamped deterministically here — the request then runs to the
        max_seq ceiling and finishes with reason "max_seq" instead of
        silently stopping short."""
        prompt = np.asarray(prompt, np.int32)
        if not 1 <= len(prompt) <= self.max_seq - 1:
            # an oversized prompt would clamp its chunk offsets into
            # earlier cache rows and "complete" with scrambled state
            raise ValueError(f"prompt length {len(prompt)} must be in "
                             f"[1, max_seq-1={self.max_seq - 1}]")
        if max_new_tokens < 1:
            # budgets0 = max_new_tokens - 1 would underflow to -1 while the
            # admit path still emits the prefill token — a request asking
            # for 0 tokens used to get 1
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        stop = self.stop_tokens if stop_tokens is None \
            else tuple(int(t) for t in stop_tokens)
        if len(stop) > self._stop_cap:
            # the (S, K) stop matrix is baked into the compiled tick
            raise ValueError(
                f"stop_tokens holds {len(stop)} ids but this engine was "
                f"built with capacity {self._stop_cap} (max(4, "
                f"len(default stop set)))")
        requested = max_new_tokens
        clamped = len(prompt) + max_new_tokens > self.max_seq
        if clamped:
            # the decode loop would stop at the max_seq - 1 ceiling anyway;
            # clamping HERE makes the effective budget visible to paging
            # (no pages reserved for tokens that can never exist) and to
            # the finish_reason ("max_seq", not a silent short "budget")
            max_new_tokens = self.max_seq - len(prompt)
        if self.kv_layout == "paged":
            need = self._need_pages(len(prompt), max_new_tokens)
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} pages ({len(prompt)} prompt + "
                    f"{max_new_tokens} new tokens at page_size="
                    f"{self.page_size}) but the pool only has "
                    f"{self.num_pages}")
        # uid comes from a monotonic counter: queue length would recycle
        # ids once requests drain, aliasing two live requests
        uid = next(self._next_uid)
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      seed=uid if seed is None else int(seed),
                      t_submit=time.perf_counter(),
                      stop_tokens=stop, requested=requested,
                      clamped=clamped)
        if self.prefix is not None:
            # hash every chunk-aligned prefix ONCE, here — admission only
            # compares precomputed keys
            req.prefix_keys = self.prefix.keys_for(prompt)
        self._queue.append(req)
        return req

    def _admit(self):
        ns, C = self.num_slots, self.prefill_chunk
        paged = self.kv_layout == "paged"
        admitted: list[tuple[int, Request]] = []
        # round plan: slot -> (matched_len, shared ids, cow page, fresh)
        plan: dict[int, tuple[int, list, int, int]] = {}
        evict_delta: dict[int, int] = {}
        reg_delta: dict[int, int] = {}
        if paged:
            # phase 1 — FIFO decisions on COUNTS only: `eff` accumulates
            # this round's pending share bumps and eviction decrements so
            # freeness checks see the round's true end state; actual page
            # ids are assigned once, at the end, exactly like the device's
            # single post-evict post-share grant pass
            eff = self.pool.refs.copy()
            free_cnt = int((eff == 0).sum())
        for slot in range(ns):
            if self.slot_req[slot] is not None or not self._queue:
                continue
            req = self._queue[0]
            if paged:
                if self.prefix is not None:
                    # pure planning — hit/miss telemetry and the LRU tick
                    # are committed below, only once admission succeeds (a
                    # backpressured head re-plans every round and must not
                    # re-count)
                    m_len, full, cow, mkey = self.prefix.match(
                        req.prefix_keys, len(req.prompt))
                else:
                    m_len, full, cow, mkey = 0, [], -1, None
                need = self._need_pages(len(req.prompt), req.max_new_tokens)
                n_fresh = need - len(full)
                # shares first: they may resurrect a cached page whose
                # refcount would otherwise read as free
                for p in full:
                    if eff[p] == 0:
                        free_cnt -= 1
                    eff[p] += 1
                if n_fresh > free_cnt and self.prefix is not None:
                    # pool dry: evict idle cached prefixes (LRU) before
                    # stalling admission
                    free_cnt += self.prefix.evict(n_fresh - free_cnt, eff,
                                                  evict_delta)
                if n_fresh > free_cnt:
                    # still dry: roll this request's shares back and hold
                    # the WHOLE queue (FIFO — skipping the head for a
                    # smaller request behind it would make admission order
                    # depend on pool state)
                    for p in full:
                        eff[p] -= 1
                        if eff[p] == 0:
                            free_cnt += 1
                    break
                free_cnt -= n_fresh
                plan[slot] = (m_len, full, cow, n_fresh)
                if self.prefix is not None:
                    self.prefix.commit(mkey, m_len)
            self._queue.pop(0)
            self.slot_req[slot] = req
            admitted.append((slot, req))
        if not admitted:
            if paged and evict_delta:
                # eviction already dropped chains from the registry; its
                # refcount decrements must land even though the round
                # admits nothing, or the evicted pages' cache refs leak
                # forever (pool reads as occupied, admission wedges, and
                # the I3 identity breaks)
                self.pool.apply_delta(evict_delta)
                ev = np.zeros((self.num_pages,), np.int32)
                for p, d in evict_delta.items():
                    ev[p] = d
                self.state = self.state._replace(
                    pages=pg.apply_refs_delta(self.state.pages,
                                              jnp.asarray(ev)))
                if self.check_invariants:
                    self._verify_invariants()
            return
        if paged:
            # phase 2 — assign page ids (mirrors the device's grant rule:
            # lowest free id first, slots in ascending order) and register
            # the admitted prompts' chains for future rounds.  Same-round
            # self-matching is impossible by construction — a chain only
            # becomes matchable after its producer's prefill ran.
            granted = self.pool.admit_round(
                [(s, plan[s][1], plan[s][3]) for s, _ in admitted],
                evict_delta)
            if self.prefix is not None:
                for slot, req in admitted:
                    self.prefix.register(req.prefix_keys,
                                         plan[slot][1] + granted[slot],
                                         reg_delta)
                self.pool.apply_register(reg_delta)
            self.pages_high_water = max(self.pages_high_water,
                                        self.pool.pages_in_use)
            self.pages_shared_high_water = max(self.pages_shared_high_water,
                                               self.pool.pages_shared)
        starts = {s: plan[s][0] if paged else 0 for s, _ in admitted}
        n_chunks = {s: max(1, -(-(len(r.prompt) - starts[s]) // C))
                    for s, r in admitted}
        for slot, req in admitted:
            req.prefill_tokens = len(req.prompt) - starts[slot]
            req.pages_shared = len(plan[slot][1]) if paged else 0
        if paged:
            for slot, req in admitted:
                self.prefill_chunks_skipped += \
                    max(1, -(-len(req.prompt) // C)) - n_chunks[slot]
        finals: dict[int, Any] = {}          # slot -> its final-chunk tokens
        P = self.num_pages
        for ci in range(max(n_chunks.values())):
            tokens = np.zeros((ns, C), np.int32)
            valid = np.zeros((ns,), bool)
            offsets = np.zeros((ns,), np.int32)
            true_lens = np.ones((ns,), np.int32)
            seeds = np.zeros((ns,), np.int32)
            budgets0 = np.zeros((ns,), np.int32)
            stops = np.full((ns, self._stop_cap), -1, np.int32)
            admitting = np.zeros((ns,), bool)
            shared = np.zeros((ns, self.pages_per_slot), np.int32)
            n_shared = np.zeros((ns,), np.int32)
            new_pages = np.zeros((ns,), np.int32)
            cow_src = np.full((ns,), -1, np.int32)
            ev_arr = np.zeros((P,), np.int32)
            rg_arr = np.zeros((P,), np.int32)
            if paged and ci == 0:
                for p, d in evict_delta.items():
                    ev_arr[p] = d
                for p, d in reg_delta.items():
                    rg_arr[p] = d
            for slot, req in admitted:
                if ci >= n_chunks[slot]:
                    continue
                off = starts[slot] + ci * C
                if paged and ci == 0:
                    m_len, full, cow, n_fresh = plan[slot]
                    admitting[slot] = True
                    shared[slot, :len(full)] = full
                    n_shared[slot] = len(full)
                    new_pages[slot] = n_fresh
                    cow_src[slot] = cow
                if ci == n_chunks[slot] - 1 and not paged:
                    # dense only: a final chunk whose padded end would
                    # cross max_seq slides back inside the cache
                    # (dynamic_update_slice would clamp the write start and
                    # scramble rows); the re-covered rows recompute to
                    # identical values.  The paged scatter drops
                    # out-of-range rows instead, so no slide is needed.
                    off = min(off, max(0, self.max_seq - C))
                piece = req.prompt[off:off + C]
                tokens[slot, :len(piece)] = piece
                valid[slot] = True
                offsets[slot] = off
                true_lens[slot] = len(req.prompt)
                seeds[slot] = req.seed
                budgets0[slot] = req.max_new_tokens - 1
                stops[slot, :len(req.stop_tokens)] = req.stop_tokens
            first = valid if ci == 0 else np.zeros((ns,), bool)
            self.state, self.caches, toks = self._admit_chunk(
                self.params, self.state, self.caches, jnp.asarray(tokens),
                jnp.asarray(valid), jnp.asarray(first), jnp.asarray(offsets),
                jnp.asarray(true_lens), jnp.asarray(seeds),
                jnp.asarray(budgets0), jnp.asarray(stops),
                jnp.asarray(admitting), jnp.asarray(shared),
                jnp.asarray(n_shared), jnp.asarray(new_pages),
                jnp.asarray(cow_src), jnp.asarray(ev_arr),
                jnp.asarray(rg_arr))
            self.n_admit_calls += 1
            for slot, req in admitted:
                if ci == n_chunks[slot] - 1:
                    finals[slot] = toks
        # one blocking sync for the whole admission round
        active = np.asarray(self.state.active)
        now = time.perf_counter()
        for slot, req in admitted:
            tok = int(np.asarray(finals[slot])[slot])
            req.out_tokens.append(tok)
            req.t_first = now
            self.n_generated += 1
            if not active[slot]:
                self._release_slot(slot)
        self.n_syncs += 1
        if self.check_invariants and paged:
            self._verify_invariants()

    def _release_slot(self, slot: int) -> None:
        """Host-side retirement: mark the request done, free the slot and
        replay the device-side refcount release in the HostPool mirror."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        if self.pool is not None:
            self.pool.release_slot(slot)
        self._finish(req)

    def _finish(self, req: Request) -> None:
        """Seal a completed request: classify the finish reason (highest
        precedence first), build the structured RequestResult and fold the
        request's speculation counters into the engine totals."""
        req.done = True
        out = req.out_tokens
        if req.aborted:
            reason = "aborted"
        elif out and out[-1] in req.stop_tokens:
            reason = "eos"
        elif req.clamped and len(out) >= req.max_new_tokens:
            # the budget was clamped at submit, so exhausting it means the
            # stream ran into the cache ceiling, not the caller's ask
            reason = "max_seq"
        elif len(out) >= req.max_new_tokens:
            reason = "budget"
        else:
            reason = "max_seq"
        self.tokens_drafted += req.drafted_tokens
        self.tokens_accepted += req.accepted_tokens
        req.result = RequestResult(
            uid=req.uid, tokens=tuple(out), finish_reason=reason,
            prefill_tokens=req.prefill_tokens,
            drafted_tokens=req.drafted_tokens,
            accepted_tokens=req.accepted_tokens,
            pages_shared=req.pages_shared,
            ttft=(req.t_first - req.t_submit) if req.t_first else None)
        self._finished.append(req.result)

    # ------------------------------------------------------------------
    # telemetry / debug
    # ------------------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        """Pages with refcount > 0 — slot-held and cache-held alike."""
        return self.pool.pages_in_use if self.pool is not None else 0

    def prefix_stats(self) -> dict:
        """Prefix-cache telemetry for reports and benches."""
        if self.prefix is None:
            return {"enabled": False, "hits": 0, "misses": 0,
                    "hit_rate": 0.0, "tokens_skipped": 0, "evictions": 0,
                    "cached_pages": 0, "chunks_skipped": 0}
        c = self.prefix
        looked = c.hits + c.misses
        return {"enabled": True, "hits": c.hits, "misses": c.misses,
                "hit_rate": c.hits / looked if looked else 0.0,
                "tokens_skipped": c.tokens_skipped,
                "evictions": c.evictions, "cached_pages": c.cached_pages,
                "chunks_skipped": self.prefill_chunks_skipped}

    def spec_stats(self) -> dict:
        """Speculation telemetry: the active drafter's identity ("ngram"
        | "model", None when speculation is off) and drafted/accepted
        totals over retired requests plus the live slots' in-flight
        counters.  `abort()` retires a running request through the same
        `_finish` path as normal completion, so its in-flight split folds
        into the totals rather than vanishing."""
        drafted, accepted = self.tokens_drafted, self.tokens_accepted
        for r in self.slot_req:
            if r is not None:
                drafted += r.drafted_tokens
                accepted += r.accepted_tokens
        return {"enabled": bool(self.draft_len),
                "draft_len": self.draft_len,
                "drafter": self.drafter_kind,
                "drafted": drafted, "accepted": accepted,
                "acceptance_rate": accepted / drafted if drafted else 0.0}

    def _verify_invariants(self) -> None:
        """Debug-mode cross-check (`check_invariants=True`): the HostPool
        mirror must equal the device allocator exactly — refcounts, free
        popcount, per-slot block tables and ownership — and the global
        refcount identity (I3 in `repro.runtime.pages`) must hold."""
        pool = self.state.pages
        refs = np.asarray(pool.refs)
        if (refs < 0).any():
            raise AssertionError(f"device refcounts negative: {refs}")
        if not np.array_equal(refs, self.pool.refs):
            raise AssertionError(
                f"host/device refcount drift:\n host {self.pool.refs}\n "
                f"device {refs}")
        if int((refs == 0).sum()) != self.pool.free_pages:
            raise AssertionError(
                f"free popcount drift: host {self.pool.free_pages}, "
                f"device {int((refs == 0).sum())}")
        n_pages = np.asarray(pool.n_pages)
        tables = np.asarray(pool.tables)
        owned = np.asarray(pool.owned)
        for s in range(self.num_slots):
            t = self.pool.slot_tables[s]
            if int(n_pages[s]) != len(t):
                raise AssertionError(
                    f"slot {s} n_pages drift: host {len(t)}, "
                    f"device {int(n_pages[s])}")
            if list(tables[s, :len(t)]) != t:
                raise AssertionError(
                    f"slot {s} table drift: host {t}, "
                    f"device {list(tables[s, :len(t)])}")
            if list(owned[s, :len(t)]) != self.pool.slot_owned[s]:
                raise AssertionError(
                    f"slot {s} ownership drift: host "
                    f"{self.pool.slot_owned[s]}, "
                    f"device {list(owned[s, :len(t)])}")
        cached = self.prefix.cached_pages if self.prefix is not None else 0
        if int(n_pages.sum()) != int(refs.sum()) - cached:
            raise AssertionError(
                f"refcount identity broken: sum(n_pages)="
                f"{int(n_pages.sum())}, sum(refs)={int(refs.sum())}, "
                f"cached={cached}")

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine tick: admit queued prompts, then `decode_steps`
        fused decode steps for all active slots (a single jit call and a
        single host sync)."""
        self._admit()
        if not any(r is not None for r in self.slot_req):
            return False
        # KV bytes this tick's decode steps read (tick-start lengths; the
        # kernel touches live pages only, the gather oracle — dense decode
        # and the speculative verify window included — always materializes
        # num_slots × max_seq rows)
        if self.decode_kernel:
            rows = pk_kernel.decode_read_rows(
                [len(r.prompt) + len(r.out_tokens)
                 for r in self.slot_req if r is not None], self.page_size)
        else:
            rows = pk_kernel.oracle_read_rows(self.num_slots, self.max_seq)
        self.kv_bytes_read += self.decode_steps * rows * self._kv_row_bytes
        self.kv_read_steps += self.decode_steps
        self.state, self.caches, toks, emitted = self._tick(
            self.params, self.state, self.caches)
        # non-spec tick: (steps, slots); spec tick: (steps, slots, window)
        # — normalize to a trailing window axis of 1
        toks = np.asarray(toks)
        emitted = np.asarray(emitted)
        if toks.ndim == 2:
            toks, emitted = toks[..., None], emitted[..., None]
        active = np.asarray(self.state.active)
        if self.draft_len:
            n_dr = np.asarray(self.state.n_drafted)
            n_ac = np.asarray(self.state.n_accepted)
        self.n_ticks += 1
        self.n_syncs += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            for t in range(toks.shape[0]):
                for j in range(toks.shape[2]):
                    if emitted[t, slot, j]:
                        req.out_tokens.append(int(toks[t, slot, j]))
                        self.n_generated += 1
            if self.draft_len:
                req.drafted_tokens = int(n_dr[slot])
                req.accepted_tokens = int(n_ac[slot])
            if not active[slot]:
                self._release_slot(slot)
        if self.check_invariants and self.kv_layout == "paged":
            self._verify_invariants()
        return True

    def run(self, max_ticks: int = 10_000) -> list[RequestResult]:
        """Serve until the queue drains (or max_ticks), returning the
        RequestResults completed during this call, completion order."""
        for _ in range(max_ticks):
            if not self.step() and not self._queue:
                break
        done, self._finished = self._finished, []
        return done

    def abort(self, req: Request) -> bool:
        """Cancel a request.  Queued: removed before it ever runs.
        Running: its slot is deactivated and (paged) its page references
        released immediately — the freed pages are grantable in the very
        next admission round.  Returns False if the request had already
        finished.  Either way an aborted request keeps the tokens it
        emitted, with finish_reason \"aborted\"."""
        if req.done:
            return False
        req.aborted = True
        if req in self._queue:
            self._queue.remove(req)
            self._finish(req)
            return True
        for slot, r in enumerate(self.slot_req):
            if r is req:
                dead = jnp.zeros((self.num_slots,), bool).at[slot].set(True)
                state = self.state._replace(active=self.state.active & ~dead)
                if self.kv_layout == "paged":
                    state = state._replace(pages=pg.release(state.pages,
                                                            dead))
                self.state = state
                self._release_slot(slot)
                if self.check_invariants and self.kv_layout == "paged":
                    self._verify_invariants()
                return True
        # not queued, not in a slot, not done — unreachable by construction
        raise AssertionError(f"request {req.uid} is in no engine structure")

    def close(self) -> None:
        """Release the engine's sharding context (the activate() in __init__
        is process-global; a later meshless Engine or trainer in the same
        process would otherwise trace against this engine's serve rules)."""
        if self._ctx is not None and shd.active() is self._ctx:
            shd.deactivate()
        self._ctx = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
