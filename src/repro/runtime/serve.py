"""Batched serving engine: slot-based continuous batching, greedy sampling,
optional BRAMAC-quantized weights (the paper's inference deployment mode).

The engine owns a fixed pool of `num_slots` sequences sharing one KV cache.
Requests are admitted into free slots (prefill writes the slot's cache
rows), and a single jit'd decode step advances *all* active slots each
tick — finished or empty slots are masked.  This is the tiling-based
inference pattern of §VI-D: weights stay resident while inputs stream.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.parallel import sharding as shd


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """`mesh` switches on the sharded decode path: the inference sharding
    profile (`serve_rules`: weights tensor-parallel over `model`, no FSDP
    all-gathers) is activated for the engine's lifetime and the parameter
    tree — float or pre-quantized `QuantizedTensor` leaves alike — is
    placed onto the mesh, so every jit'd prefill/decode below runs
    tensor-parallel."""

    def __init__(self, cfg, params, num_slots: int, max_seq: int,
                 eos_id: int | None = None, mesh=None,
                 capacity_factor: float | None = None,
                 dispatch: str | None = None):
        # mesh may be a jax Mesh or a composed-mesh spec ("model=4",
        # "data=2,model=4", "2x4", 4, ...) resolved by sharding.build_mesh.
        # capacity_factor / dispatch override the MoE routing knobs on cfg
        # (moe_capacity_factor / ep_dispatch) for this engine — the jit'd
        # prefill/decode close over cfg, so the override must happen here,
        # before any tracing.
        if dispatch is not None:
            if dispatch not in ("global", "per_source"):
                raise ValueError(f"dispatch must be 'global' or "
                                 f"'per_source', got {dispatch!r}")
            cfg = cfg.replace(ep_dispatch=dispatch)
        if capacity_factor is not None:
            cfg = cfg.replace(moe_capacity_factor=float(capacity_factor))
        if mesh is not None and not isinstance(mesh, jax.sharding.Mesh):
            mesh = shd.build_mesh(mesh)
        self.mesh = mesh
        self._ctx = None
        if mesh is not None:
            self._ctx = shd.activate(mesh,
                                     shd.serve_rules("pod" in mesh.axis_names))
            params = jax.device_put(params,
                                    shd.param_shardings(params, self._ctx))
        self.cfg, self.params = cfg, params
        self.num_slots, self.max_seq = num_slots, max_seq
        self.eos_id = eos_id
        self._next_uid = itertools.count()
        self.caches = M.init_cache(cfg, num_slots, max_seq)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.positions = np.zeros((num_slots,), np.int32)
        self.budgets = np.zeros((num_slots,), np.int32)
        self.last_tok = np.zeros((num_slots,), np.int32)
        self._queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, q: M.decode_step(p, t, cfg, c, q))
        # prefill is jit'd per prompt length (padded to buckets of 16);
        # recurrent mixers (mamba/xlstm) can't skip padding in their state,
        # so those archs prefill at exact length (bucket = 1)
        recurrent = any(m in spec for spec in cfg.layer_pattern
                        for m in ("mamba", "mlstm", "slstm"))
        self._bucket_q = 1 if recurrent else 16
        self._prefills: dict[int, Any] = {}

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16) -> Request:
        # uid comes from a monotonic counter: queue length would recycle
        # ids once requests drain, aliasing two live requests
        req = Request(uid=next(self._next_uid), prompt=np.asarray(prompt,
                                                                  np.int32),
                      max_new_tokens=max_new_tokens)
        self._queue.append(req)
        return req

    def _prefill_fn(self, plen: int):
        if plen not in self._prefills:
            cfg = self.cfg

            def one(params, tokens, true_len, caches):
                """Prefill ONE prompt (B=1), then scatter into its slot.
                Prompts are padded to a length bucket; logits are read at
                the true last position (padding rows in the cache get
                overwritten as decode advances)."""
                # cache leaves are (n_periods, B, ...) — slice the batch dim
                c1 = jax.tree_util.tree_map(lambda a: a[:, :1], caches)
                pos0 = jnp.zeros((1,), jnp.int32)
                logits, _, c1 = M.forward(params, {"tokens": tokens[None]},
                                          cfg, caches=c1, cache_pos=pos0)
                return logits[0, true_len - 1], c1

            self._prefills[plen] = jax.jit(one)
        return self._prefills[plen]

    def _admit(self):
        for slot in range(self.num_slots):
            if self.slot_req[slot] is None and self._queue:
                req = self._queue.pop(0)
                plen = _bucket(len(req.prompt), self._bucket_q)
                padded = np.zeros((plen,), np.int32)
                padded[:len(req.prompt)] = req.prompt
                last_logits, c1 = self._prefill_fn(plen)(
                    self.params, jnp.asarray(padded),
                    jnp.int32(len(req.prompt)), self.caches)
                # scatter the B=1 cache rows into this slot (batch is dim 1)
                self.caches = jax.tree_util.tree_map(
                    lambda full, one: full.at[:, slot].set(one[:, 0]),
                    self.caches, c1)
                tok = int(jnp.argmax(last_logits))
                req.out_tokens.append(tok)
                self.slot_req[slot] = req
                self.positions[slot] = len(req.prompt)
                self.budgets[slot] = req.max_new_tokens - 1
                self.last_tok[slot] = tok

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit + one decode for all active slots."""
        self._admit()
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return False
        toks = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.positions)
        logits, self.caches = self._decode(self.params, toks, self.caches,
                                           pos)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.positions[slot] += 1
            if self.budgets[slot] > 0:
                req.out_tokens.append(int(nxt[slot]))
                self.last_tok[slot] = nxt[slot]
                self.budgets[slot] -= 1
                if (self.eos_id is not None
                        and nxt[slot] == self.eos_id):
                    self.budgets[slot] = 0
            if self.budgets[slot] <= 0 or \
                    self.positions[slot] >= self.max_seq - 1:
                req.done = True
                self.slot_req[slot] = None
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step() and not self._queue:
                break

    def close(self) -> None:
        """Release the engine's sharding context (the activate() in __init__
        is process-global; a later meshless Engine or trainer in the same
        process would otherwise trace against this engine's serve rules)."""
        if self._ctx is not None and shd.active() is self._ctx:
            shd.deactivate()
        self._ctx = None


def _bucket(n: int, q: int = 16) -> int:
    if q == 1:
        return n
    return max(q, ((n + q - 1) // q) * q)
