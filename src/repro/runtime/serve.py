"""Device-resident continuous-batching engine (the paper's §VI-D
tiling-based inference mode: quantized weights stay resident, inputs
stream).

The engine owns a fixed pool of `num_slots` sequences sharing one KV
cache, plus a `SlotState` pytree (last token, position, budget, active
mask, per-slot PRNG key, and — in the paged layout — the block tables and
the free-page list) that lives on device for the engine's lifetime.  The
serving loop is compiled data-flow, not Python control-flow — two jit'd
functions do all the work:

  admit  — chunked prefill: every queued prompt is cut into fixed-size
           chunks (`prefill_chunk`; 1 for recurrent mixers, which cannot
           skip padding in their state) and one compiled function per
           chunk prefills ALL admitting slots at once: full-batch forward
           at per-slot cache offsets, masked merge of the touched slots'
           cache rows, and — on each prompt's final chunk — on-device
           sampling of the first token and the slot-state commit.  No
           per-prompt-length recompiles, no host-side full-cache scatter.

  tick   — fused multi-step decode: `decode_steps` iterations of
           decode -> sample (greedy / temperature / top-k / top-p, keyed
           by the per-request seed) -> EOS + budget + max_seq termination
           masking, rolled into ONE jit via `lax.scan`.  The host syncs
           once per tick — i.e. once per `decode_steps` tokens — and gets
           back the (steps, slots) token block plus emission masks.

KV layouts (`kv_layout=`):

  "paged" (default) — the BRAMAC memory discipline applied to the cache:
           attention KV lives in a shared pool of fixed `cfg.page_size`-row
           pages ("BRAM-array-sized" blocks) addressed through per-slot
           int32 block tables.  Pages are granted at admission (lowest
           free page id first — deterministic), writes scatter through the
           table inside the jit'd forward, and a request's pages return to
           the device-resident free list the moment it terminates inside
           the fused tick (or at admission, for first-token EOS).  When
           the pool runs dry the admitter exerts backpressure: queued
           requests wait, FIFO, until a terminating request reclaims
           enough pages.  Co-resident requests are therefore bounded by
           total live tokens — not `num_slots × max_seq` — while greedy
           token streams stay bit-identical to the dense layout (masked
           pool rows contribute exact zeros to the softmax, like the dense
           cache's untouched rows).

  "dense" — the PR-4 layout: every slot reserves `max_seq` KV rows up
           front; kept as the parity oracle and for kernels that want the
           contiguous reservation.

The Python `Engine` is a thin wrapper holding the request queue and the
host mirror of slot/page occupancy; it is also a context manager so the
process-global sharding ctx activated by `mesh=` is released even when
serving raises.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.runtime import sampling as smp


class SlotState(NamedTuple):
    """Per-slot decode state; one device-resident pytree for all slots.

    `tables` / `n_pages` / `free` are the paged-KV bookkeeping (empty
    arrays under the dense layout): `tables[s, j]` is the pool page
    holding slot s's rows [j*page_size, (j+1)*page_size), `n_pages[s]`
    how many table entries are live, and `free` the shared free-page
    mask that allocation (admit) and reclaim (tick) edit on device."""
    last_tok: jax.Array     # (S,) i32  last sampled token (next decode input)
    pos: jax.Array          # (S,) i32  next cache index to write
    budget: jax.Array       # (S,) i32  tokens still to emit after this one
    active: jax.Array       # (S,) bool slot is mid-generation
    rng: jax.Array          # (S, 2) u32 per-request sampling key chain
    tables: jax.Array       # (S, max_pages) i32 block tables (paged)
    n_pages: jax.Array      # (S,) i32  pages allocated per slot (paged)
    free: jax.Array         # (P,) bool free-page mask (paged)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    seed: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0          # wall time the first token landed (TTFT)


def _alloc_pages(free, tables, n_pages, new_pages):
    """Grant `new_pages[s]` pages to each admitting slot s from the shared
    free mask, lowest free page id first (stable argsort — deterministic
    placement).  Admitting slots start empty (their previous occupant's
    pages were reclaimed), so grants overwrite table entries from 0."""
    P = free.shape[0]
    mp = tables.shape[1]
    order = jnp.argsort(~free, stable=True)          # free page ids first
    starts = jnp.cumsum(new_pages) - new_pages       # (S,) offsets in order
    j = jnp.arange(mp, dtype=jnp.int32)[None, :]
    take = j < new_pages[:, None]                    # (S, mp) granted entries
    grant = order[jnp.clip(starts[:, None] + j, 0, P - 1)].astype(jnp.int32)
    tables = jnp.where(take, grant, tables)
    free = free.at[jnp.where(take, grant, P)].set(False, mode="drop")
    n_pages = jnp.where(new_pages > 0, new_pages, n_pages)
    return free, tables, n_pages


def _reclaim_pages(free, tables, n_pages, dead):
    """Return every page owned by a `dead` slot to the free mask.  Stale
    table entries are left in place — they are only ever read through the
    causal mask (exact-zero contributions) until the slot is re-granted."""
    P = free.shape[0]
    j = jnp.arange(tables.shape[1], dtype=jnp.int32)[None, :]
    owned = dead[:, None] & (j < n_pages[:, None])
    free = free.at[jnp.where(owned, tables, P)].set(True, mode="drop")
    return free, jnp.where(dead, 0, n_pages)


class Engine:
    """`mesh` switches on the sharded decode path: the inference sharding
    profile (`serve_rules`: weights tensor-parallel over `model`, no FSDP
    all-gathers) is activated for the engine's lifetime and the parameter
    tree — float or pre-quantized `QuantizedTensor` leaves alike — is
    placed onto the mesh, so every jit'd prefill/decode below runs
    tensor-parallel.

    Sampling and scheduling knobs (all baked into the compiled functions,
    so they must be set at construction):
      sampling      — "greedy" | "temperature" | "top_k" | "top_p", or a
                      ready-made `sampling.SamplingConfig`
      temperature / top_k / top_p — parameters of the stochastic methods
      decode_steps  — decode steps fused per tick (host syncs per
                      generated token scale as 1/decode_steps)
      prefill_chunk — prompt chunk size for admission (forced to 1 on
                      recurrent mixers); one jit serves every length
      seed          — engine base seed; a request's stream is keyed by
                      fold_in(base, request.seed) only, so it reproduces
                      across slots and co-batched traffic
      kv_layout     — "paged" (default) or "dense" (see module docstring)
      num_pages     — paged pool size; default num_slots * ceil(max_seq /
                      cfg.page_size) (capacity-equal to dense — shrink it
                      to trade co-residency for memory)
    """

    def __init__(self, cfg, params, num_slots: int, max_seq: int,
                 eos_id: int | None = None, mesh=None,
                 capacity_factor: float | None = None,
                 dispatch: str | None = None,
                 sampling: str | smp.SamplingConfig = "greedy",
                 temperature: float = 1.0, top_k: int = 0, top_p: float = 1.0,
                 decode_steps: int = 1, prefill_chunk: int = 16,
                 seed: int = 0, kv_layout: str = "paged",
                 num_pages: int | None = None):
        # mesh may be a jax Mesh or a composed-mesh spec ("model=4",
        # "data=2,model=4", "2x4", 4, ...) resolved by sharding.build_mesh.
        # capacity_factor / dispatch override the MoE routing knobs on cfg
        # (moe_capacity_factor / ep_dispatch) for this engine — the jit'd
        # prefill/decode close over cfg, so the override must happen here,
        # before any tracing.
        if dispatch is not None:
            if dispatch not in ("global", "per_source"):
                raise ValueError(f"dispatch must be 'global' or "
                                 f"'per_source', got {dispatch!r}")
            cfg = cfg.replace(ep_dispatch=dispatch)
        if capacity_factor is not None:
            cfg = cfg.replace(moe_capacity_factor=float(capacity_factor))
        if isinstance(sampling, str):
            sampling = smp.SamplingConfig(method=sampling,
                                          temperature=temperature,
                                          top_k=top_k, top_p=top_p)
        if decode_steps < 1:
            raise ValueError(f"decode_steps must be >= 1, got {decode_steps}")
        if kv_layout not in ("paged", "dense"):
            raise ValueError(f"kv_layout must be 'paged' or 'dense', "
                             f"got {kv_layout!r}")
        if mesh is not None and not isinstance(mesh, jax.sharding.Mesh):
            mesh = shd.build_mesh(mesh)
        self.mesh = mesh
        self._ctx = None
        if mesh is not None:
            self._ctx = shd.activate(mesh,
                                     shd.serve_rules("pod" in mesh.axis_names))
            params = jax.device_put(params,
                                    shd.param_shardings(params, self._ctx))
        self.cfg, self.params = cfg, params
        self.num_slots, self.max_seq = num_slots, max_seq
        self.eos_id = eos_id
        self.sampling = sampling
        self.decode_steps = decode_steps
        # recurrent mixers (mamba/mlstm/slstm) can't skip padding in their
        # state, so their prompts are fed token-by-token (chunk = 1); a
        # chunk can never exceed the cache (its write must fit max_seq)
        recurrent = any(m in spec for spec in cfg.layer_pattern
                        for m in ("mamba", "mlstm", "slstm"))
        self.prefill_chunk = 1 if recurrent \
            else max(1, min(prefill_chunk, max_seq - 1))
        self._next_uid = itertools.count()
        self._base_key = jax.random.PRNGKey(seed)
        # --- KV layout ---
        self.kv_layout = kv_layout
        self.page_size = cfg.page_size
        self.pages_per_slot = -(-max_seq // self.page_size)  # table length
        if kv_layout == "paged":
            self.num_pages = int(num_pages) if num_pages is not None \
                else num_slots * self.pages_per_slot
            if self.num_pages < 1:
                raise ValueError(f"num_pages must be >= 1, "
                                 f"got {self.num_pages}")
            self.caches = M.init_cache(cfg, num_slots, max_seq,
                                       num_pages=self.num_pages)
            self._pool_flags = M.cache_pool_flags(cfg)
            mp, P = self.pages_per_slot, self.num_pages
        else:
            self.num_pages = 0
            self.caches = M.init_cache(cfg, num_slots, max_seq)
            self._pool_flags = None
            mp, P = 0, 0
        self.state = SlotState(
            last_tok=jnp.zeros((num_slots,), jnp.int32),
            pos=jnp.zeros((num_slots,), jnp.int32),
            budget=jnp.zeros((num_slots,), jnp.int32),
            active=jnp.zeros((num_slots,), bool),
            rng=jnp.zeros((num_slots, 2), jnp.uint32),
            tables=jnp.zeros((num_slots, mp), jnp.int32),
            n_pages=jnp.zeros((num_slots,), jnp.int32),
            free=jnp.ones((P,), bool))
        self.slot_req: list[Request | None] = [None] * num_slots
        self._queue: list[Request] = []
        # host mirror of pool occupancy: updated at admit (grant) and at
        # the post-sync done scan (reclaim), so backpressure decisions
        # never need an extra device sync
        self.pages_in_use = 0
        self.pages_high_water = 0
        self._slot_pages = [0] * num_slots
        # host<->device sync accounting for the serving bench: one sync per
        # jit'd tick / per admission round, regardless of decode_steps
        self.n_ticks = 0
        self.n_admit_calls = 0
        self.n_syncs = 0
        self.n_generated = 0
        # buffer donation lets caches/state update in place; the CPU
        # backend doesn't implement donation and would warn on every call
        donate = () if jax.default_backend() == "cpu" else (1, 2)
        self._tick = jax.jit(self._make_tick(), donate_argnums=donate)
        self._admit_chunk = jax.jit(self._make_admit_chunk(),
                                    donate_argnums=donate)

    # ------------------------------------------------------------------
    # compiled data-flow
    # ------------------------------------------------------------------

    def _paged_kv(self, state):
        """The PagedKV bundle for one traced call; write_mask is supplied
        by the caller (valid slots at admit, active slots in the tick)."""
        def bundle(write_mask):
            return attn.PagedKV(tables=state.tables, n_pages=state.n_pages,
                                write_mask=write_mask, max_seq=self.max_seq,
                                page_size=self.page_size)
        return bundle

    def _make_tick(self):
        """N fused decode steps: decode -> sample -> terminate, scanned;
        under the paged layout, pages of every slot that terminates inside
        the tick return to the free list before the host ever syncs."""
        cfg, sc = self.cfg, self.sampling
        eos, max_seq, steps = self.eos_id, self.max_seq, self.decode_steps
        paged_mode = self.kv_layout == "paged"

        def tick(params, state, caches):
            def body(carry, _):
                state, caches = carry
                # inactive slots must not write: their stale block-table
                # entries may point at pages since re-granted to another
                # request (dense slots own their rows, so masking there is
                # unnecessary — and the PR-4 path stays untouched)
                pv = self._paged_kv(state)(state.active) if paged_mode \
                    else None
                logits, caches = M.decode_step(
                    params, state.last_tok[:, None], cfg, caches, state.pos,
                    paged=pv)
                toks, keys = smp.sample(logits, state.rng, sc)
                emit = state.active
                tok = jnp.where(emit, toks, state.last_tok)
                rng = jnp.where(emit[:, None], keys, state.rng)
                pos = jnp.where(emit, state.pos + 1, state.pos)
                budget = jnp.where(emit, state.budget - 1, state.budget)
                hit_eos = (emit & (tok == eos)) if eos is not None \
                    else jnp.zeros_like(emit)
                active = emit & (budget > 0) & ~hit_eos & (pos < max_seq - 1)
                new = state._replace(last_tok=tok, pos=pos, budget=budget,
                                     active=active, rng=rng)
                return (new, caches), (tok, emit)

            pre_active = state.active
            (state, caches), (toks, emitted) = jax.lax.scan(
                body, (state, caches), None, length=steps)
            if paged_mode:
                dead = pre_active & ~state.active
                free, n_pages = _reclaim_pages(state.free, state.tables,
                                               state.n_pages, dead)
                state = state._replace(free=free, n_pages=n_pages)
            return state, caches, toks, emitted

        return tick

    def _make_admit_chunk(self):
        """One prefill chunk for every admitting slot, in one call.

        tokens (S, C) holds each admitting slot's chunk (garbage rows for
        slots mid-decode are masked out of the cache merge); offsets are
        the per-slot chunk starts.  Rows whose chunk completes the prompt
        (`final`) sample their first token on device and commit the slot
        state; the sampled tokens come back so the host can append them.
        Under the paged layout the first chunk also carries each admitting
        slot's page grant (`new_pages`), allocated on device from the free
        mask before the forward runs."""
        cfg, sc = self.cfg, self.sampling
        eos, max_seq, ns = self.eos_id, self.max_seq, self.num_slots
        base_key = self._base_key
        paged_mode = self.kv_layout == "paged"
        pool_flags = self._pool_flags

        def admit(params, state, caches, tokens, valid, offsets, true_lens,
                  seeds, budgets0, new_pages):
            C = tokens.shape[1]
            if paged_mode:
                free, tables, n_pages = _alloc_pages(
                    state.free, state.tables, state.n_pages, new_pages)
                state = state._replace(free=free, tables=tables,
                                       n_pages=n_pages)
            # a slot's FIRST chunk starts from pristine state: recurrent
            # mixers accumulate (h/conv/C/n/m carry the previous occupant
            # forward — the seed engine's whole-prompt *_sequence prefill
            # implicitly started from zeros), and KV rows revert to their
            # init values rather than stale garbage (XLA folds the init
            # tree into constants; no second cache is held).  Shared page
            # pools are exempt: co-resident requests own live rows there,
            # and stale rows only ever surface masked to exact zeros.
            first = valid & (offsets == 0)

            def reset(cur, ini):
                m = first.reshape((1, ns) + (1,) * (cur.ndim - 2))
                return jnp.where(m, ini.astype(cur.dtype), cur)

            if paged_mode:
                init_tree = M.init_cache(cfg, ns, max_seq,
                                         num_pages=free.shape[0])
                caches = jax.tree_util.tree_map(
                    lambda cur, ini, pool: cur if pool else reset(cur, ini),
                    caches, init_tree, pool_flags)
            else:
                caches = jax.tree_util.tree_map(
                    reset, caches, M.init_cache(cfg, ns, max_seq))
            # unembed only each slot's true last prompt row (the one whose
            # logits can be sampled), not all C chunk positions
            idx = jnp.clip(true_lens - 1 - offsets, 0, C - 1)
            pv = self._paged_kv(state)(valid) if paged_mode else None
            logits, _, new_caches = M.forward(
                params, {"tokens": tokens}, cfg, caches=caches,
                cache_pos=offsets, gather_pos=idx, paged=pv)

            def merge(old, new):
                m = valid.reshape((1, ns) + (1,) * (old.ndim - 2))
                return jnp.where(m, new.astype(old.dtype), old)

            if paged_mode:
                # pool leaves already masked their writes at scatter time;
                # per-slot leaves (recurrent state, xattn) merge as before
                caches = jax.tree_util.tree_map(
                    lambda old, new, pool: new if pool else merge(old, new),
                    caches, new_caches, pool_flags)
            else:
                caches = jax.tree_util.tree_map(merge, caches, new_caches)
            last = logits[:, 0]                                 # (S, V)
            final = valid & (offsets + C >= true_lens)
            keys0 = smp.request_keys(base_key, seeds)
            toks, keys = smp.sample(last, keys0, sc)
            hit_eos = (final & (toks == eos)) if eos is not None \
                else jnp.zeros_like(final)
            act = final & (budgets0 > 0) & ~hit_eos \
                & (true_lens < max_seq - 1)
            state = state._replace(
                last_tok=jnp.where(final, toks, state.last_tok),
                pos=jnp.where(final, true_lens, state.pos),
                budget=jnp.where(final, budgets0, state.budget),
                active=jnp.where(final, act, state.active),
                rng=jnp.where(final[:, None], keys, state.rng))
            if paged_mode:
                # a request that terminates AT admission (first token EOS,
                # or no decode room) must give its pages back right here
                dead = final & ~act
                free, n_pages = _reclaim_pages(state.free, state.tables,
                                               state.n_pages, dead)
                state = state._replace(free=free, n_pages=n_pages)
            return state, caches, toks

        return admit

    # ------------------------------------------------------------------
    # host-side request plumbing
    # ------------------------------------------------------------------

    def _need_pages(self, prompt_len: int, max_new: int) -> int:
        """Pages a request occupies for its whole lifetime: prompt rows
        plus one KV row per decode step (the first token comes from the
        prefill logits), clipped to the max_seq-1 generation ceiling."""
        rows = min(prompt_len + max_new - 1, self.max_seq - 1)
        return -(-rows // self.page_size)

    def submit(self, prompt, max_new_tokens: int = 16,
               seed: int | None = None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if not 1 <= len(prompt) <= self.max_seq - 1:
            # an oversized prompt would clamp its chunk offsets into
            # earlier cache rows and "complete" with scrambled state
            raise ValueError(f"prompt length {len(prompt)} must be in "
                             f"[1, max_seq-1={self.max_seq - 1}]")
        if max_new_tokens < 1:
            # budgets0 = max_new_tokens - 1 would underflow to -1 while the
            # admit path still emits the prefill token — a request asking
            # for 0 tokens used to get 1
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        if self.kv_layout == "paged":
            need = self._need_pages(len(prompt), max_new_tokens)
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} pages ({len(prompt)} prompt + "
                    f"{max_new_tokens} new tokens at page_size="
                    f"{self.page_size}) but the pool only has "
                    f"{self.num_pages}")
        # uid comes from a monotonic counter: queue length would recycle
        # ids once requests drain, aliasing two live requests
        uid = next(self._next_uid)
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      seed=uid if seed is None else int(seed),
                      t_submit=time.perf_counter())
        self._queue.append(req)
        return req

    def _admit(self):
        ns, C = self.num_slots, self.prefill_chunk
        paged = self.kv_layout == "paged"
        admitted: list[tuple[int, Request]] = []
        grants: dict[int, int] = {}
        for slot in range(ns):
            if self.slot_req[slot] is not None or not self._queue:
                continue
            if paged:
                need = self._need_pages(len(self._queue[0].prompt),
                                        self._queue[0].max_new_tokens)
                if self.pages_in_use + need > self.num_pages:
                    # pool exhausted: hold the WHOLE queue (FIFO — skipping
                    # the head for a smaller request behind it would make
                    # admission order depend on pool state)
                    break
                grants[slot] = need
                self.pages_in_use += need
                self._slot_pages[slot] = need
            req = self._queue.pop(0)
            self.slot_req[slot] = req
            admitted.append((slot, req))
        self.pages_high_water = max(self.pages_high_water, self.pages_in_use)
        if not admitted:
            return
        n_chunks = {s: max(1, -(-len(r.prompt) // C)) for s, r in admitted}
        finals: dict[int, Any] = {}          # slot -> its final-chunk tokens
        for ci in range(max(n_chunks.values())):
            tokens = np.zeros((ns, C), np.int32)
            valid = np.zeros((ns,), bool)
            offsets = np.zeros((ns,), np.int32)
            true_lens = np.ones((ns,), np.int32)
            seeds = np.zeros((ns,), np.int32)
            budgets0 = np.zeros((ns,), np.int32)
            new_pages = np.zeros((ns,), np.int32)
            for slot, req in admitted:
                if ci >= n_chunks[slot]:
                    continue
                off = ci * C
                if ci == 0 and paged:
                    new_pages[slot] = grants[slot]
                if ci == n_chunks[slot] - 1 and not paged:
                    # dense only: a final chunk whose padded end would
                    # cross max_seq slides back inside the cache
                    # (dynamic_update_slice would clamp the write start and
                    # scramble rows); the re-covered rows recompute to
                    # identical values.  The paged scatter drops
                    # out-of-range rows instead, so no slide is needed.
                    off = min(off, max(0, self.max_seq - C))
                piece = req.prompt[off:off + C]
                tokens[slot, :len(piece)] = piece
                valid[slot] = True
                offsets[slot] = off
                true_lens[slot] = len(req.prompt)
                seeds[slot] = req.seed
                budgets0[slot] = req.max_new_tokens - 1
            self.state, self.caches, toks = self._admit_chunk(
                self.params, self.state, self.caches, jnp.asarray(tokens),
                jnp.asarray(valid), jnp.asarray(offsets),
                jnp.asarray(true_lens), jnp.asarray(seeds),
                jnp.asarray(budgets0), jnp.asarray(new_pages))
            self.n_admit_calls += 1
            for slot, req in admitted:
                if ci == n_chunks[slot] - 1:
                    finals[slot] = toks
        # one blocking sync for the whole admission round
        active = np.asarray(self.state.active)
        now = time.perf_counter()
        for slot, req in admitted:
            tok = int(np.asarray(finals[slot])[slot])
            req.out_tokens.append(tok)
            req.t_first = now
            self.n_generated += 1
            if not active[slot]:
                self._release_slot(slot)
        self.n_syncs += 1

    def _release_slot(self, slot: int) -> None:
        """Host-side retirement: mark the request done, free the slot and
        mirror the device-side page reclaim in the occupancy counters."""
        self.slot_req[slot].done = True
        self.slot_req[slot] = None
        self.pages_in_use -= self._slot_pages[slot]
        self._slot_pages[slot] = 0

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine tick: admit queued prompts, then `decode_steps`
        fused decode steps for all active slots (a single jit call and a
        single host sync)."""
        self._admit()
        if not any(r is not None for r in self.slot_req):
            return False
        self.state, self.caches, toks, emitted = self._tick(
            self.params, self.state, self.caches)
        toks = np.asarray(toks)                       # (steps, slots)
        emitted = np.asarray(emitted)
        active = np.asarray(self.state.active)
        self.n_ticks += 1
        self.n_syncs += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            for t in range(toks.shape[0]):
                if emitted[t, slot]:
                    req.out_tokens.append(int(toks[t, slot]))
                    self.n_generated += 1
            if not active[slot]:
                self._release_slot(slot)
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step() and not self._queue:
                break

    def close(self) -> None:
        """Release the engine's sharding context (the activate() in __init__
        is process-global; a later meshless Engine or trainer in the same
        process would otherwise trace against this engine's serve rules)."""
        if self._ctx is not None and shd.active() is self._ctx:
            shd.deactivate()
        self._ctx = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
