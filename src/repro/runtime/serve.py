"""Device-resident continuous-batching engine (the paper's §VI-D
tiling-based inference mode: quantized weights stay resident, inputs
stream).

Since PR 10 the engine is a thin COMPOSITION of three layers:

  runtime.scheduler  — every host decision: the FIFO queue, admission
                       planning with backpressure, the `pages.HostPool`
                       mirror(s), the prefix registry, request
                       lifecycle and results.
  runtime.workers    — every device computation: `PrefillWorker` owns
                       the chunked admit path, `DecodeWorker` the fused
                       multi-step tick (plain or speculative) — both
                       compiled once at construction.
  Engine (here)      — the composition and the public API (`submit`,
                       `step`, `run`, `abort`, telemetry), unchanged
                       from the pre-split engine: a colocated Engine
                       points both workers at the SAME state/caches and
                       streams bit-identically to the monolith.

KV layouts (`kv_layout=`):

  "paged" (default) — the BRAMAC memory discipline applied to the cache:
           attention KV lives in a shared pool of fixed `cfg.page_size`-row
           pages ("BRAM-array-sized" blocks) addressed through per-slot
           int32 block tables.  ALL pool mutation goes through the
           refcounted allocator in `repro.runtime.pages` — grants at
           admission (lowest free page id first — deterministic),
           refcount-bumped read-only shares for prefix-cache hits,
           release-to-zero reclaim the moment a request terminates inside
           the fused tick (or at admission, for first-token EOS).  When
           the pool runs dry the admitter first evicts idle cached
           prefixes (LRU), then exerts backpressure: queued requests
           wait, FIFO, until a terminating request reclaims enough pages.
           Greedy token streams stay bit-identical to the dense layout
           (masked pool rows contribute exact zeros to the softmax, like
           the dense cache's untouched rows).

  "dense" — the PR-4 layout: every slot reserves `max_seq` KV rows up
           front; kept as the parity oracle and for kernels that want the
           contiguous reservation.

Disaggregated mode (`disagg=True` / `EngineOptions.disagg`, paged
layout only): prefill and decode run as SEPARATE workers with separate
page pools and slot sets.  A prompt admits into the prefill worker's
pool, prefills there (first token sampled at admission, so TTFT is
unchanged), then its KV pages move into the decode worker's pool at
page granularity — `pages.export_pages` gathers the tiles, the decode
mirror grants destination ids by the same lowest-free-id rule, and
`pages.import_pages`/`adopt` land contents bit-exactly (invariant I7
in `runtime/pages.py`; `check_invariants=True` verifies I1–I7 on BOTH
pools after every transfer round).  When the decode pool is dry or no
decode slot is free the transfer backpressures FIFO; greedy streams
stay bit-identical to the colocated engine.  `role="both"` runs both
workers in-process (today's only transport); "prefill"/"decode" name
the endpoints of the future multi-process transport and raise
NotImplementedError.  Prefix caching and speculation switch off under
disaggregation, and archs with per-slot cache leaves (recurrent
hybrids, xattn) are rejected — their state has no page representation
to transfer.

Prefix caching (`prefix_cache=True`, paged layout only): prompts are
hashed at `submit` in fixed `prefix_chunk`-token pieces; admission maps
the longest cached prefix's full pages into the slot's block table
read-only (skipping their prefill compute entirely, so warm-prefix TTFT
drops by ~the shared length) and the slot's own prefill starts at the
matched offset.  A partially covered page is handed over as a private
copy (copy-on-write) instead, so cached pages are never written.
Recurrent-hybrid archs opt out silently (their state accumulates over
every token) but stream identically.

Speculative decoding (`EngineOptions.speculation.draft_len > 0`): each
tick step drafts `draft_len` tokens from the configured drafter —
`drafter="ngram"` (default), a device-resident per-slot n-gram table,
or `drafter="model"`, the serving model's own weights requantized to
`draft_bits` (2-bit BRAMAC datapath) decoding through a private
per-slot draft KV cache that rides inside SlotState
(`runtime/speculate.py`) — scores the whole window [last_tok, g_1..g_d]
in ONE forward through the same chunked path prefill uses, and
accepts/replaces every position on device (`sampling.spec_verify`).
Greedy streams are bit-identical to non-speculative decoding
(invariants A1-A6 in speculate.py); the host still syncs once per tick
whatever the acceptance length.  Recurrent-hybrid, cross-attention and
MoE archs opt out silently, and the model drafter additionally opts
out of the prefix cache.

Construction: `Engine(cfg, params, options=EngineOptions(...))` is the
primary constructor (`repro.runtime.options`); the historic flat kwargs
are still accepted and merged over `options` via `EngineOptions.build`.
Completed requests carry a structured `options.RequestResult` (tokens,
finish_reason, prefill/speculation/page-sharing counters) in
`Request.result`, and `Engine.run` returns the results completed during
the call.

The Engine is a context manager so the process-global sharding ctx
activated by `mesh=` is released even when serving raises — including
when `__init__` itself raises after activation (construction cleans up
behind itself and `close()` is idempotent).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import paged_attention as pk_kernel
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.runtime import pages as pg
from repro.runtime import speculate as spc
from repro.runtime.options import EngineOptions, RequestResult
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.workers import (DecodeWorker, PrefillWorker, SlotState,
                                   init_slot_state)

__all__ = ["Engine", "Request", "SlotState", "RequestResult"]


class Engine:
    """`mesh` switches on the sharded decode path: the inference sharding
    profile (`serve_rules`: weights tensor-parallel over `model`, no FSDP
    all-gathers) is activated for the engine's lifetime and the parameter
    tree — float or pre-quantized `QuantizedTensor` leaves alike — is
    placed onto the mesh, so every jit'd prefill/decode below runs
    tensor-parallel.

    Sampling and scheduling knobs (all baked into the compiled functions,
    so they must be set at construction):
      sampling      — "greedy" | "temperature" | "top_k" | "top_p", or a
                      ready-made `sampling.SamplingConfig`
      temperature / top_k / top_p — parameters of the stochastic methods
      decode_steps  — decode steps fused per tick (host syncs per
                      generated token scale as 1/decode_steps)
      prefill_chunk — prompt chunk size for admission (forced to 1 on
                      recurrent mixers); one jit serves every length
      seed          — engine base seed; a request's stream is keyed by
                      fold_in(base, request.seed) only, so it reproduces
                      across slots and co-batched traffic
      stop_tokens   — default per-request stop set; `eos_id=N` is legacy
                      shorthand for stop_tokens=(N,), and submit's
                      stop_tokens= overrides per request
      draft_len     — speculative draft window per decode step; 0 (the
                      default) disables speculation entirely
      spec_ngram / spec_table — n-gram order and per-slot table buckets
                      of the self-speculation drafter (speculate.py)
      drafter       — "ngram" (default) or "model": the 2-bit BRAMAC
                      draft model (the serving weights requantized to
                      draft_bits, optionally truncated to draft_layers
                      blocks) proposing through a private draft KV cache
      kv_layout     — "paged" (default) or "dense" (see module docstring)
      num_pages     — paged pool size; default num_slots * ceil(max_seq /
                      cfg.page_size) (capacity-equal to dense — shrink it
                      to trade co-residency for memory)
      prefix_cache  — share cached prompt prefixes across requests
                      (paged layout only; recurrent mixers opt out)
      prefix_chunk  — prefix hash granularity in tokens (default
                      cfg.page_size; smaller values trade more
                      copy-on-write splits for finer matching)
      prefix_max_chains — registry capacity: LRU chains beyond this are
                      evicted at registration time, bounding host memory
                      under high-cardinality traffic (default 4096)
      disagg        — split prefill and decode into separate workers with
                      separate page pools; see the module docstring and
                      `options.DisaggOptions` (role / prefill_slots /
                      prefill_pages)
      check_invariants — verify the HostPool mirror(s) against the device
                      allocator(s) (refcounts, free popcount, block
                      tables; under disagg also the I7 bit-exact transfer
                      check) after every sync; debug aid, costs extra
                      transfers
    """

    def __init__(self, cfg, params, num_slots: int | None = None,
                 max_seq: int | None = None, *,
                 options: EngineOptions | None = None, **legacy):
        # `options` is the primary constructor surface; any flat legacy
        # kwargs (including the positional num_slots/max_seq) are merged
        # over it by EngineOptions.build, which owns all validation.
        if num_slots is not None:
            legacy["num_slots"] = num_slots
        if max_seq is not None:
            legacy["max_seq"] = max_seq
        options = EngineOptions.build(base=options, **legacy)
        # close() must be callable on a partially constructed engine: the
        # sharding ctx is process-global, so a construction that raises
        # AFTER activate (drafter validation, cache init OOM, ...) would
        # otherwise leave it held and poison every later Engine/trainer
        # in the process.
        self._ctx = None
        self.mesh = None
        try:
            self._build(cfg, params, options)
        except BaseException:
            self.close()
            raise

    def _build(self, cfg, params, options: EngineOptions) -> None:
        self.options = options
        sch, par, dis = options.schedule, options.parallel, options.disagg
        num_slots, max_seq = sch.num_slots, sch.max_seq
        # capacity_factor / dispatch override the MoE routing knobs on cfg
        # (moe_capacity_factor / ep_dispatch) for this engine — the jit'd
        # prefill/decode close over cfg, so the override must happen here,
        # before any tracing.
        if par.dispatch is not None:
            cfg = cfg.replace(ep_dispatch=par.dispatch)
        if par.capacity_factor is not None:
            cfg = cfg.replace(moe_capacity_factor=float(par.capacity_factor))
        mesh = par.mesh
        if mesh is not None and not isinstance(mesh, jax.sharding.Mesh):
            mesh = shd.build_mesh(mesh)
        self.mesh = mesh
        if mesh is not None:
            self._ctx = shd.activate(mesh,
                                     shd.serve_rules("pod" in mesh.axis_names))
            params = jax.device_put(params,
                                    shd.param_shardings(params, self._ctx))
        self.cfg, self.params = cfg, params
        self.num_slots, self.max_seq = num_slots, max_seq
        self.stop_tokens = sch.stop_tokens
        # legacy attr: the single-token stop set older callers passed
        self.eos_id = sch.stop_tokens[0] if len(sch.stop_tokens) == 1 \
            else None
        self.sampling = options.sampling
        self.decode_steps = sch.decode_steps
        self.check_invariants = options.debug.check_invariants
        # recurrent mixers (mamba/mlstm/slstm) can't skip padding in their
        # state, so their prompts are fed token-by-token (chunk = 1); a
        # chunk can never exceed the cache (its write must fit max_seq)
        recurrent = any(m in spec for spec in cfg.layer_pattern
                        for m in ("mamba", "mlstm", "slstm"))
        self.prefill_chunk = 1 if recurrent \
            else max(1, min(sch.prefill_chunk, max_seq - 1))
        # --- disaggregation (paged + meshless + pool-representable only:
        # the transfer unit is the page, so every cache leaf must live in
        # the shared pool — recurrent/xattn per-slot state cannot move)
        self.disagg = bool(dis.enabled)
        if self.disagg:
            if dis.role in ("prefill", "decode"):
                raise NotImplementedError(
                    f"role={dis.role!r} is the single-process endpoint of "
                    f"the multi-process transport, which is not implemented "
                    f"yet — the page-transfer seam (pages.export_pages / "
                    f"import_pages) is where it plugs in; use role='both'")
            if options.paging.kv_layout != "paged":
                raise ValueError("disaggregation requires kv_layout="
                                 "'paged': pages are the transfer unit")
            if mesh is not None:
                raise ValueError("disaggregation and mesh= are mutually "
                                 "exclusive (single-process transport)")
            if not all(jax.tree_util.tree_leaves(M.cache_pool_flags(cfg))):
                raise ValueError(
                    "disaggregation requires every cache leaf to live in "
                    "the shared page pool; recurrent/xattn per-slot state "
                    f"has no page representation to transfer "
                    f"(layer_pattern={cfg.layer_pattern})")
        # --- speculation (silent opt-outs: recurrent state cannot rewind
        # a rejected draft; xattn decode needs vision inputs; MoE capacity
        # drops depend on tokens-per-call, breaking verify/decode parity;
        # disagg drafter state has no page representation to transfer)
        spec_ok = not recurrent and not self.disagg \
            and not any("xattn" in s or "moe" in s
                        for s in cfg.layer_pattern)
        self.draft_len = min(options.speculation.draft_len,
                             max(0, max_seq - 2)) if spec_ok else 0
        self.drafter_kind = options.speculation.drafter \
            if self.draft_len else None
        if not self.draft_len:
            self.drafter = None
        elif self.drafter_kind == "model":
            # the 2-bit BRAMAC draft model: the engine's own weights
            # requantized, with a private per-slot draft KV cache riding
            # inside SlotState (speculate.QuantDrafter, invariant A6)
            self.drafter = spc.QuantDrafter.build(
                cfg, params, max_seq,
                bits=options.speculation.draft_bits,
                draft_layers=options.speculation.draft_layers)
        else:
            self.drafter = spc.NGramDrafter(options.speculation.ngram,
                                            options.speculation.table)
        self._stop_cap = max(4, len(self.stop_tokens))
        self._base_key = jax.random.PRNGKey(sch.seed)
        # --- KV layout ---
        self.kv_layout = options.paging.kv_layout
        self.page_size = cfg.page_size
        self.pages_per_slot = -(-max_seq // self.page_size)  # table length
        paged = self.kv_layout == "paged"
        if paged:
            self.num_pages = int(options.paging.num_pages) \
                if options.paging.num_pages is not None \
                else num_slots * self.pages_per_slot
            self._pool_flags = M.cache_pool_flags(cfg)
            mp, P = self.pages_per_slot, self.num_pages
        else:
            self.num_pages = 0
            self._pool_flags = None
            mp, P = 0, 0
        # disagg sizing: the prefill worker's own slot set and pool
        self.prefill_slots = (int(dis.prefill_slots)
                              if dis.prefill_slots is not None
                              else num_slots) if self.disagg else num_slots
        self.prefill_pages = (int(dis.prefill_pages)
                              if dis.prefill_pages is not None
                              else self.prefill_slots * self.pages_per_slot) \
            if self.disagg else self.num_pages
        # dense speculative rollback routes through the KV leaf flags
        # (same tree structure as the paged pool flags)
        self._kv_flags = M.cache_pool_flags(cfg) \
            if self.draft_len and self.kv_layout == "dense" else None
        # --- pallas decode kernel (Sq=1 paged reads walk the block table
        # page by page; None = auto: real TPU only — interpret mode on CPU
        # is correct but slow, so CPU callers opt in).  The speculative
        # tick verifies Sq=draft+1 windows and keeps the gather oracle, as
        # does any mesh-sharded engine (the kernel carries no partition
        # annotations).
        dk = options.paging.decode_kernel
        self.decode_kernel = bool(
            paged and mesh is None and not self.draft_len
            and (dk if dk is not None
                 else jax.default_backend() == "tpu"))
        # --- prefix cache (paged only; recurrent state accumulates over
        # every token, so those archs cannot share prefixes — they opt out
        # silently but stream identically.  The model drafter opts out
        # too: a warm-prefix chunk skips its prefill compute, which would
        # leave the corresponding DRAFT-cache rows unwritten and break
        # invariant A6.  Disaggregation opts out as well: cached pages
        # would pin the prefill pool while the decode reads happen in the
        # other pool — streams stay bit-identical, admission just runs
        # the full prefill) ---
        self.prefix_chunk = int(options.prefix.chunk) \
            if options.prefix.chunk is not None else self.page_size
        enabled = options.prefix.enabled and paged and not recurrent \
            and self.drafter_kind != "model" and not self.disagg
        prefix = pg.PrefixCache(self.prefix_chunk, self.page_size,
                                max_chains=options.prefix.max_chains) \
            if enabled else None
        # --- the host-side scheduler (admission side = prefill side) ---
        self.sched = Scheduler(
            num_slots=self.prefill_slots, max_seq=max_seq,
            page_size=self.page_size, prefill_chunk=self.prefill_chunk,
            paged=paged,
            num_pages=self.prefill_pages if self.disagg else self.num_pages,
            stop_cap=self._stop_cap, stop_tokens=self.stop_tokens,
            prefix=prefix)
        if self.disagg:
            self.sched.attach_decode(num_slots, self.num_pages)
        # --- the device-facing workers ---
        self.prefill = PrefillWorker(
            cfg=cfg, num_slots=self.prefill_slots, max_seq=max_seq,
            prefill_chunk=self.prefill_chunk, stop_cap=self._stop_cap,
            sampling=self.sampling, base_key=self._base_key,
            kv_layout=self.kv_layout, pool_flags=self._pool_flags,
            draft_len=self.draft_len, drafter=self.drafter)
        self.decode = DecodeWorker(
            cfg=cfg, num_slots=num_slots, max_seq=max_seq,
            decode_steps=self.decode_steps, sampling=self.sampling,
            kv_layout=self.kv_layout, decode_kernel=self.decode_kernel,
            draft_len=self.draft_len, drafter=self.drafter,
            pool_flags=self._pool_flags, kv_flags=self._kv_flags)
        # --- device state: one state/caches pair per pool (a colocated
        # engine has exactly one — both workers share it)
        draft0 = self.drafter.init_state(num_slots) if self.draft_len \
            else spc.empty_state(num_slots)
        self.state = init_slot_state(num_slots, self._stop_cap, mp,
                                     self.num_pages if paged else 0, draft0)
        self.caches = M.init_cache(cfg, num_slots, max_seq,
                                   num_pages=self.num_pages) if paged \
            else M.init_cache(cfg, num_slots, max_seq)
        if self.disagg:
            self.p_state = init_slot_state(
                self.prefill_slots, self._stop_cap, mp, self.prefill_pages,
                spc.empty_state(self.prefill_slots))
            self.p_caches = M.init_cache(cfg, self.prefill_slots, max_seq,
                                         num_pages=self.prefill_pages)
        # pool-occupancy telemetry; occupancy itself lives in the HostPool
        # mirror(s), kept in lockstep with the device allocator(s) so
        # backpressure never needs an extra sync.  pages_high_water always
        # tracks the DECODE-side pool (the colocated engine's only pool).
        self.pages_high_water = 0
        self.pages_shared_high_water = 0
        self.prefill_pages_high_water = 0
        self.prefill_chunks_skipped = 0
        # disagg transfer telemetry
        self.pages_transferred = 0
        self.transfer_rounds = 0
        # host<->device sync accounting for the serving bench: one sync per
        # jit'd tick / per admission round, regardless of decode_steps
        self.n_ticks = 0
        self.n_admit_calls = 0
        self.n_syncs = 0
        self.n_generated = 0
        # decode KV read accounting (kernels/paged_attention currency):
        # bytes the decode path reads from the KV cache, accumulated per
        # tick from the tick-start slot lengths (allocation is fixed
        # within a tick, so this undercounts each slot by at most one
        # page over the tick — deterministic given the same schedule).
        self.kv_bytes_read = 0
        self.kv_read_steps = 0
        self._kv_row_bytes = pk_kernel.kv_row_bytes(cfg)

    # ------------------------------------------------------------------
    # back-compat surface: the host structures moved into the Scheduler
    # ------------------------------------------------------------------

    @property
    def pool(self) -> pg.HostPool | None:
        """The decode-side HostPool mirror (the colocated engine's only
        pool); None under the dense layout."""
        return self.sched.decode_pool

    @property
    def prefix(self) -> pg.PrefixCache | None:
        return self.sched.prefix

    @property
    def slot_req(self) -> list:
        """Decode-side slot occupancy (the colocated engine's only slot
        registry)."""
        return self.sched.decode_slot_req

    @property
    def _queue(self) -> list:
        return self.sched.queue

    @property
    def tokens_drafted(self) -> int:
        return self.sched.tokens_drafted

    @property
    def tokens_accepted(self) -> int:
        return self.sched.tokens_accepted

    # ------------------------------------------------------------------
    # host-side request plumbing
    # ------------------------------------------------------------------

    def _need_pages(self, prompt_len: int, max_new: int) -> int:
        return self.sched._need_pages(prompt_len, max_new)

    def submit(self, prompt, max_new_tokens: int = 16,
               seed: int | None = None,
               stop_tokens: tuple | None = None) -> Request:
        """Queue a prompt.  `stop_tokens` overrides the engine's default
        stop set for this request (any emitted token in the set ends the
        stream, finish_reason="eos").  A budget that cannot fit the cache
        is clamped deterministically here — the request then runs to the
        max_seq ceiling and finishes with reason "max_seq" instead of
        silently stopping short."""
        return self.sched.submit(prompt, max_new_tokens, seed, stop_tokens)

    def _admit_side(self):
        """(state, caches) of the pool admission lands in: the prefill
        worker's own pool under disagg, THE pool otherwise."""
        return (self.p_state, self.p_caches) if self.disagg \
            else (self.state, self.caches)

    def _set_admit_side(self, state, caches) -> None:
        if self.disagg:
            self.p_state, self.p_caches = state, caches
        else:
            self.state, self.caches = state, caches

    def _admit(self) -> None:
        paged = self.kv_layout == "paged"
        rnd = self.sched.plan_round()
        if rnd is None:
            return
        if not rnd.admitted:
            # eviction-only round: the registry already dropped its
            # chains host-side; commit the decrements on the device pool
            st, ca = self._admit_side()
            P = st.pages.refs.shape[0]
            ev = np.zeros((P,), np.int32)
            for p, d in rnd.evict_delta.items():
                ev[p] = d
            st = st._replace(pages=pg.apply_refs_delta(st.pages,
                                                       jnp.asarray(ev)))
            self._set_admit_side(st, ca)
            if self.check_invariants:
                self._verify_invariants()
            return
        if paged:
            hw = self.sched.pool.pages_in_use
            if self.disagg:
                self.prefill_pages_high_water = max(
                    self.prefill_pages_high_water, hw)
            else:
                self.pages_high_water = max(self.pages_high_water, hw)
            self.pages_shared_high_water = max(self.pages_shared_high_water,
                                               self.sched.pool.pages_shared)
        self.prefill_chunks_skipped += rnd.chunks_skipped
        st, ca = self._admit_side()
        st, ca, finals, n_calls = self.prefill.run_round(self.params, st,
                                                         ca, rnd)
        self._set_admit_side(st, ca)
        self.n_admit_calls += n_calls
        # one blocking sync for the whole admission round
        active = np.asarray(st.active)
        now = time.perf_counter()
        for slot, req in rnd.admitted:
            tok = int(np.asarray(finals[slot])[slot])
            req.out_tokens.append(tok)
            req.t_first = now
            self.n_generated += 1
            if not active[slot]:
                # terminated at admission (first-token EOS / no decode
                # room): the compiled admit already released its device
                # refs; retire it on the admission side — it never
                # transfers
                self.sched.release_admit_slot(slot)
            elif self.disagg:
                self.sched.mark_ready(slot)
        self.n_syncs += 1
        if self.check_invariants and paged:
            self._verify_invariants()

    def _transfer(self) -> None:
        """Disagg: move every transferable prefilled request's pages
        into the decode pool (FIFO, backpressured by the scheduler)."""
        plans = self.sched.plan_transfers()
        if not plans:
            return
        mp = self.pages_per_slot
        checked = [] if self.check_invariants else None
        for t in plans:
            src = np.zeros((mp,), np.int32)
            src[:t.n] = t.src_ids
            dst = np.zeros((mp,), np.int32)
            dst[:t.n] = t.dst_ids
            self.p_state, tiles, scalars = self.prefill.export_request(
                self.p_state, self.p_caches, jnp.asarray(src), t.src_slot)
            self.state, self.caches = self.decode.import_request(
                self.state, self.caches, tiles, scalars, jnp.asarray(dst),
                t.n, t.dst_slot)
            self.pages_transferred += t.n
            if checked is not None:
                checked.append((t, tiles))
        self.transfer_rounds += 1
        self.pages_high_water = max(self.pages_high_water,
                                    self.sched.decode_pool.pages_in_use)
        if self.check_invariants:
            self._verify_invariants()
            for t, tiles in checked:
                self._verify_transfer(t, tiles)

    # ------------------------------------------------------------------
    # telemetry / debug
    # ------------------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        """Decode-pool pages with refcount > 0 — slot-held and
        cache-held alike."""
        pool = self.sched.decode_pool
        return pool.pages_in_use if pool is not None else 0

    def prefix_stats(self) -> dict:
        """Prefix-cache telemetry for reports and benches."""
        if self.prefix is None:
            return {"enabled": False, "hits": 0, "misses": 0,
                    "hit_rate": 0.0, "tokens_skipped": 0, "evictions": 0,
                    "cached_pages": 0, "chunks_skipped": 0}
        c = self.prefix
        looked = c.hits + c.misses
        return {"enabled": True, "hits": c.hits, "misses": c.misses,
                "hit_rate": c.hits / looked if looked else 0.0,
                "tokens_skipped": c.tokens_skipped,
                "evictions": c.evictions, "cached_pages": c.cached_pages,
                "chunks_skipped": self.prefill_chunks_skipped}

    def spec_stats(self) -> dict:
        """Speculation telemetry: the active drafter's identity ("ngram"
        | "model", None when speculation is off) and drafted/accepted
        totals over retired requests plus the live slots' in-flight
        counters.  `abort()` retires a running request through the same
        finish path as normal completion, so its in-flight split folds
        into the totals rather than vanishing."""
        drafted, accepted = self.tokens_drafted, self.tokens_accepted
        for r in self.sched.decode_slot_req:
            if r is not None:
                drafted += r.drafted_tokens
                accepted += r.accepted_tokens
        return {"enabled": bool(self.draft_len),
                "draft_len": self.draft_len,
                "drafter": self.drafter_kind,
                "drafted": drafted, "accepted": accepted,
                "acceptance_rate": accepted / drafted if drafted else 0.0}

    def disagg_stats(self) -> dict:
        """Disaggregation telemetry: the transfer volume and both pools'
        high-water occupancy (all zeros on a colocated engine)."""
        return {"enabled": self.disagg,
                "pages_transferred": self.pages_transferred,
                "transfer_rounds": self.transfer_rounds,
                "transfers_backpressured":
                    self.sched.transfers_backpressured,
                "decode_pages_high_water": self.pages_high_water,
                "decode_pages": self.num_pages,
                "prefill_pages_high_water": self.prefill_pages_high_water,
                "prefill_pages": self.prefill_pages if self.disagg else 0,
                "prefill_slots": self.prefill_slots if self.disagg else 0}

    def _verify_pool(self, host: pg.HostPool, dev: pg.PagePool,
                     num_slots: int, cached: int, label: str) -> None:
        """One pool's mirror-vs-device cross-check: refcounts, free
        popcount, per-slot block tables/ownership, and the I3 identity."""
        refs = np.asarray(dev.refs)
        if (refs < 0).any():
            raise AssertionError(f"[{label}] device refcounts negative: "
                                 f"{refs}")
        if not np.array_equal(refs, host.refs):
            raise AssertionError(
                f"[{label}] host/device refcount drift:\n host "
                f"{host.refs}\n device {refs}")
        if int((refs == 0).sum()) != host.free_pages:
            raise AssertionError(
                f"[{label}] free popcount drift: host {host.free_pages}, "
                f"device {int((refs == 0).sum())}")
        n_pages = np.asarray(dev.n_pages)
        tables = np.asarray(dev.tables)
        owned = np.asarray(dev.owned)
        for s in range(num_slots):
            t = host.slot_tables[s]
            if int(n_pages[s]) != len(t):
                raise AssertionError(
                    f"[{label}] slot {s} n_pages drift: host {len(t)}, "
                    f"device {int(n_pages[s])}")
            if list(tables[s, :len(t)]) != t:
                raise AssertionError(
                    f"[{label}] slot {s} table drift: host {t}, "
                    f"device {list(tables[s, :len(t)])}")
            if list(owned[s, :len(t)]) != host.slot_owned[s]:
                raise AssertionError(
                    f"[{label}] slot {s} ownership drift: host "
                    f"{host.slot_owned[s]}, "
                    f"device {list(owned[s, :len(t)])}")
        if int(n_pages.sum()) != int(refs.sum()) - cached:
            raise AssertionError(
                f"[{label}] refcount identity broken: sum(n_pages)="
                f"{int(n_pages.sum())}, sum(refs)={int(refs.sum())}, "
                f"cached={cached}")

    def _verify_invariants(self) -> None:
        """Debug-mode cross-check (`check_invariants=True`): every
        HostPool mirror must equal its device allocator exactly —
        refcounts, free popcount, per-slot block tables and ownership —
        and the global refcount identity (I3 in `repro.runtime.pages`)
        must hold.  Under disagg BOTH pools are checked (I7: each side
        independently satisfies I1–I6 after every transfer round)."""
        if self.kv_layout != "paged":
            return
        if self.disagg:
            self._verify_pool(self.sched.pool, self.p_state.pages,
                              self.prefill_slots, 0, "prefill")
            self._verify_pool(self.sched.decode_pool, self.state.pages,
                              self.num_slots, 0, "decode")
        else:
            cached = self.prefix.cached_pages \
                if self.prefix is not None else 0
            self._verify_pool(self.sched.pool, self.state.pages,
                              self.num_slots, cached, "pool")

    def _verify_transfer(self, t, tiles) -> None:
        """I7 content check: the imported pages' rows must read back
        bit-identical to the exported tiles."""
        mp = self.pages_per_slot
        dst = np.zeros((mp,), np.int32)
        dst[:t.n] = t.dst_ids
        got = pg.export_pages(self.caches, self._pool_flags,
                              jnp.asarray(dst))
        for a, b in zip(jax.tree_util.tree_leaves(tiles),
                        jax.tree_util.tree_leaves(got)):
            a = np.asarray(a)[:, :t.n]
            b = np.asarray(b)[:, :t.n]
            if not np.array_equal(a, b):
                raise AssertionError(
                    f"I7 broken: transferred pages for request "
                    f"{t.req.uid} differ between pools")

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine tick: admit queued prompts (disagg: after moving
        transferable prefilled requests into the decode pool, freeing
        prefill slots for this round), then `decode_steps` fused decode
        steps for all active decode slots (a single jit call and a
        single host sync)."""
        if self.disagg:
            self._transfer()
        self._admit()
        if self.disagg:
            # freshly prefilled prompts move the same step, so their
            # first decode tick lands exactly when the colocated
            # engine's would
            self._transfer()
        if not any(r is not None for r in self.sched.decode_slot_req):
            if self.disagg:
                # prefill-side work (queued, mid-prefill or awaiting
                # transfer) still counts as engine progress
                return bool(self.sched.queue or self.sched.ready
                            or any(r is not None
                                   for r in self.sched.slot_req))
            return False
        # KV bytes this tick's decode steps read (tick-start lengths; the
        # kernel touches live pages only, the gather oracle — dense decode
        # and the speculative verify window included — always materializes
        # num_slots × max_seq rows)
        if self.decode_kernel:
            rows = pk_kernel.decode_read_rows(
                [len(r.prompt) + len(r.out_tokens)
                 for r in self.sched.decode_slot_req if r is not None],
                self.page_size)
        else:
            rows = pk_kernel.oracle_read_rows(self.num_slots, self.max_seq)
        self.kv_bytes_read += self.decode_steps * rows * self._kv_row_bytes
        self.kv_read_steps += self.decode_steps
        self.state, self.caches, toks, emitted = self.decode.tick(
            self.params, self.state, self.caches)
        # non-spec tick: (steps, slots); spec tick: (steps, slots, window)
        # — normalize to a trailing window axis of 1
        toks = np.asarray(toks)
        emitted = np.asarray(emitted)
        if toks.ndim == 2:
            toks, emitted = toks[..., None], emitted[..., None]
        active = np.asarray(self.state.active)
        if self.draft_len:
            n_dr = np.asarray(self.state.n_drafted)
            n_ac = np.asarray(self.state.n_accepted)
        self.n_ticks += 1
        self.n_syncs += 1
        for slot, req in enumerate(self.sched.decode_slot_req):
            if req is None:
                continue
            for t in range(toks.shape[0]):
                for j in range(toks.shape[2]):
                    if emitted[t, slot, j]:
                        req.out_tokens.append(int(toks[t, slot, j]))
                        self.n_generated += 1
            if self.draft_len:
                req.drafted_tokens = int(n_dr[slot])
                req.accepted_tokens = int(n_ac[slot])
            if not active[slot]:
                self.sched.release_decode_slot(slot)
        if self.check_invariants and self.kv_layout == "paged":
            self._verify_invariants()
        return True

    def run(self, max_ticks: int = 10_000) -> list[RequestResult]:
        """Serve until the queue drains (or max_ticks), returning the
        RequestResults completed during this call, completion order."""
        for _ in range(max_ticks):
            if not self.step() and not self.sched.queue:
                break
        done, self.sched.finished = self.sched.finished, []
        return done

    def abort(self, req: Request) -> bool:
        """Cancel a request.  Queued: removed before it ever runs.
        Running: its slot is deactivated and (paged) its page references
        released immediately — the freed pages are grantable in the very
        next admission round.  Disagg: a prefilled request awaiting
        transfer is dropped on the prefill side and never moves.
        Returns False if the request had already finished.  Either way
        an aborted request keeps the tokens it emitted, with
        finish_reason "aborted"."""
        if req.done:
            return False
        req.aborted = True
        if req in self.sched.queue:
            self.sched.queue.remove(req)
            self.sched.finish(req)
            return True
        if self.disagg and req.uid in self.sched._ready_slot:
            slot = self.sched.drop_ready(req)
            dead = jnp.zeros((self.prefill_slots,), bool).at[slot].set(True)
            self.p_state = self.p_state._replace(
                active=self.p_state.active & ~dead,
                pages=pg.release(self.p_state.pages, dead))
            self.sched.release_admit_slot(slot)
            if self.check_invariants:
                self._verify_invariants()
            return True
        for slot, r in enumerate(self.sched.decode_slot_req):
            if r is req:
                dead = jnp.zeros((self.num_slots,), bool).at[slot].set(True)
                state = self.state._replace(active=self.state.active & ~dead)
                if self.kv_layout == "paged":
                    state = state._replace(pages=pg.release(state.pages,
                                                            dead))
                self.state = state
                self.sched.release_decode_slot(slot)
                if self.check_invariants and self.kv_layout == "paged":
                    self._verify_invariants()
                return True
        # not queued, not in a slot, not done — unreachable by construction
        raise AssertionError(f"request {req.uid} is in no engine structure")

    def close(self) -> None:
        """Release the engine's sharding context (the activate() in
        __init__ is process-global; a later meshless Engine or trainer in
        the same process would otherwise trace against this engine's
        serve rules).  Idempotent, and safe on a partially constructed
        engine — __init__ calls it before re-raising."""
        if self._ctx is not None and shd.active() is self._ctx:
            shd.deactivate()
        self._ctx = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
