"""AdamW with optional block-wise int8-quantized moments (8-bit Adam).

ZeRO-3 note: optimizer state pytrees mirror the parameter pytree, so the
same `param_shardings` place them — states are born sharded across
(pod, data) with no replication, which together with FSDP parameters is
what fits jamba-398B training on a 256-chip pod (DESIGN.md §4).

The quantized state is the paper-aligned beyond-paper trick: BRAMAC's
premise is that low-precision integers + per-group scales retain DNN
fidelity.  The first moment is block-wise absmax int8 (1 B/param); the
second moment spans too many orders of magnitude for *linear* int8 (the
reason bitsandbytes uses a dynamic-exponent code), so it is kept in
bfloat16 (2 B/param) whose 8-bit exponent covers the range exactly.
Total m+v: 8 → 3 bytes/param — what fits jamba-398B on one 256-chip pod.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_state: bool = True     # int8 m and v


# ---------------------------------------------------------------------------
# block-wise int8 state quantization
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class Q8:
    """Block-wise absmax int8 tensor (shape/size are static aux data)."""

    def __init__(self, q, scale, shape):
        self.q, self.scale, self.shape = q, scale, tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(*children, shape)


def _q8(x: jax.Array) -> Q8:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.round(blocks / scale).astype(jnp.int8)
    return Q8(q, scale.astype(jnp.float32), x.shape)


def _dq8(s: Q8) -> jax.Array:
    flat = (s.q.astype(jnp.float32) * s.scale).reshape(-1)
    size = 1
    for d in s.shape:
        size *= d
    return flat[:size].reshape(s.shape)


def _qtree(tree):
    return jax.tree_util.tree_map(_q8, tree)


def _dqtree(tree):
    return jax.tree_util.tree_map(
        _dq8, tree, is_leaf=lambda x: isinstance(x, Q8))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def init(params: Any, cfg: AdamWConfig) -> dict:
    def zeros():
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m, v = zeros(), zeros()            # distinct buffers (donation-safe)
    if cfg.quantize_state:
        m = _qtree(m)
        v = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), v)
    return {"step": jnp.zeros((), jnp.int32), "m": m, "v": v}


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def apply(params: Any, state: dict, grads: Any, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    m_full = _dqtree(state["m"]) if cfg.quantize_state else state["m"]
    v_full = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), state["v"]) \
        if cfg.quantize_state else state["v"]

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                         # decoupled decay, matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return new_p, m, v

    out = jax.tree_util.tree_map(upd, params, grads, m_full, v_full)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    if cfg.quantize_state:
        new_m = _qtree(new_m)
        new_v = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.bfloat16), new_v)
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm}


def lr_schedule(step, base_lr, warmup=100, total=10_000, min_frac=0.1):
    """Linear warmup + cosine decay."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(1.0, warmup)
    prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)
