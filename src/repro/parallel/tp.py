"""Tensor-parallel quantized matmul — explicit collectives over the `model`
axis (§VI's many-tile scale-out, across devices instead of BRAMs).

Two partitionings of `quant_matmul`, both bit-exact against the
single-device kernel:

  * **K-sharded (row-parallel)** — each model shard holds a (M, K/n)
    activation slice and the matching (K/n, N) weight rows, runs the BRAMAC
    kernel with *unit scales* so the shard result is the raw int32
    accumulator, and an integer `psum` reduces partial sums across shards
    before a single dequant epilogue.  The cross-device psum plays exactly
    the role of the dummy-array Accumulator row: partials meet in integer
    domain, so blocking/sharding cannot perturb the result.
  * **N-sharded (column-parallel)** — each shard owns full-K columns of the
    weight (and their per-column scales); no reduction is needed and the
    global out_specs concatenation assembles the output.

Exactness caveat (inherent to the kernel's float32 epilogue): integer
accumulators are exact up to 2**24; per-shard partials are smaller than the
single-device accumulator, so any (bits, K) that is exact on one device is
exact sharded.

The physical mesh axis defaults to the active logical-axis rule set in
`parallel.sharding` (`tp` → "model"), so callers that already `activate()`d
a mesh get consistent placement for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops
from repro.parallel import sharding
from repro.parallel.compat import shard_map


def _tp_axis(mesh: Mesh, axis: str | None) -> str:
    """Resolve the physical TP axis: explicit arg > active `tp` rule >
    "model"."""
    if axis is not None:
        return axis
    ctx = sharding.active()
    if ctx is not None:
        phys = ctx.rules.get("tp")
        if isinstance(phys, str):
            return phys
    return "model"


def tp_quant_matmul(x_q, w_q, x_scale, w_scale, *, mesh: Mesh,
                    bits_a: int, bits_w: int, axis: str | None = None,
                    partition: str = "k", signed: bool = True,
                    out_dtype=jnp.float32, use_kernel: bool = True):
    """Tensor-parallel (M,K)x(K,N) quantized matmul on `mesh`.

    partition="k": row-parallel with int32 partial-sum psum.
    partition="n": column-parallel, output assembled across shards.
    Inputs are the same logical operands as `ops.quant_matmul`; sharding is
    applied internally via shard_map in_specs, so callers pass full arrays
    (or arrays already placed to match the specs).
    """
    M, K = x_q.shape
    N = w_q.shape[-1]
    ax = _tp_axis(mesh, axis)
    n_shards = mesh.shape[ax]
    xs = jnp.broadcast_to(jnp.asarray(x_scale, jnp.float32), (M, 1))
    ws = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (1, N))

    if partition == "k":
        if K % n_shards:
            raise ValueError(f"K={K} not divisible by {n_shards}-way "
                             f"'{ax}' axis")
        one = jnp.ones((1, 1), jnp.float32)

        def row_parallel(xq, wq):
            acc = ops.quant_matmul(xq, wq, one, one, bits_a=bits_a,
                                   bits_w=bits_w, signed=signed,
                                   out_dtype=jnp.int32,
                                   use_kernel=use_kernel)
            return jax.lax.psum(acc, ax)

        acc = shard_map(row_parallel, mesh=mesh,
                        in_specs=(P(None, ax), P(ax, None)),
                        out_specs=P(None, None),
                        check_vma=False)(x_q, w_q)
        return (acc.astype(jnp.float32) * xs * ws).astype(out_dtype)

    if partition == "n":
        if N % n_shards:
            raise ValueError(f"N={N} not divisible by {n_shards}-way "
                             f"'{ax}' axis")

        def col_parallel(xq, wq, xsl, wsl):
            return ops.quant_matmul(xq, wq, xsl, wsl, bits_a=bits_a,
                                    bits_w=bits_w, signed=signed,
                                    out_dtype=out_dtype,
                                    use_kernel=use_kernel)

        return shard_map(col_parallel, mesh=mesh,
                         in_specs=(P(None, None), P(None, ax),
                                   P(None, None), P(None, ax)),
                         out_specs=P(None, ax),
                         check_vma=False)(x_q, w_q, xs, ws)

    raise ValueError(f"partition must be 'k' or 'n', got {partition!r}")
