"""Version-portable `shard_map` (JAX 0.4.x → current).

`shard_map` has moved twice across JAX releases:

  * ≤ 0.4.x / 0.5.x: `jax.experimental.shard_map.shard_map(...)` with the
    replication checker flag spelled `check_rep`;
  * ≥ 0.6: promoted to `jax.shard_map(...)` with the flag renamed
    `check_vma` (varying-manual-axes), and the old experimental path
    deprecated then removed.

Runtime code in this repo must run on whichever JAX the container bakes in
(currently 0.4.37, which has *neither* `jax.shard_map` nor `check_vma`), so
every `shard_map` call site goes through this module: it resolves the
implementation once, accepts both flag spellings, and translates to whatever
the resolved implementation understands.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

_IMPL: Callable[..., Any] | None = None
_IMPL_PARAMS: frozenset[str] | None = None


def _resolve() -> Callable[..., Any]:
    """Pick the shard_map implementation available on this JAX."""
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    return impl


def _impl() -> tuple[Callable[..., Any], frozenset[str]]:
    global _IMPL, _IMPL_PARAMS
    if _IMPL is None:
        _IMPL = _resolve()
        try:
            _IMPL_PARAMS = frozenset(inspect.signature(_IMPL).parameters)
        except (TypeError, ValueError):      # C-accelerated / exotic wrapper
            _IMPL_PARAMS = frozenset()
    return _IMPL, _IMPL_PARAMS


def shard_map(f: Callable[..., Any], /, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None, check_rep: bool | None = None,
              **kwargs):
    """Map `f` over shards of a mesh — portable across JAX versions.

    `check_vma` and `check_rep` are aliases for the same knob (the
    replication/varying-axes checker); pass either and it is forwarded
    under the name the installed JAX understands, or dropped if the
    installed JAX has no such knob.
    """
    if check_vma is not None and check_rep is not None and \
            check_vma != check_rep:
        raise ValueError("check_vma and check_rep are aliases; "
                         f"got conflicting values {check_vma} != {check_rep}")
    impl, params = _impl()
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        if "check_vma" in params:
            kwargs["check_vma"] = check
        elif "check_rep" in params:
            kwargs["check_rep"] = check
        # neither spelling exists: the checker is gone on this version; drop
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **kwargs)
