"""Logical-axis sharding: DP / FSDP(ZeRO-3) / TP / EP / SP rules.

Models call `constrain(x, *logical_axes)`; the launcher activates a mesh +
rule set before tracing.  With no activation (unit tests, single CPU) every
constraint is a no-op, so model code never depends on a mesh.

Rule sets map logical axis names → physical mesh axes:

  batch    : data-parallel batch dim            → ("pod", "data")
  fsdp     : ZeRO-3 parameter shard dim         → ("pod", "data")
  tp       : tensor-parallel (heads/ff/vocab)   → "model"
  act_seq  : sequence-parallel residual stream  → "model"
  kv_feat  : decode KV-cache feature shard      → "model"

Parameter placement is name-based: `param_specs(params)` walks the pytree
and assigns (fsdp, tp) on the (in, out) dims of column-parallel weights and
(tp, fsdp) on row-parallel ones, experts on (tp→EP, fsdp, ·), everything
else replicated.  Stacked layer params get a leading None for the period
axis.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: dict

    def resolve(self, *logical) -> P:
        axes = []
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            phys = self.rules.get(name)
            axes.append(phys)
        return P(*axes)

    def phys_axis(self, logical: str) -> str | None:
        """The single physical mesh axis `logical` maps to, or None when the
        rule is absent, multi-axis, or names an axis this mesh doesn't have
        (callers use this to decide whether a collective path can run)."""
        phys = self.rules.get(logical)
        if isinstance(phys, str) and phys in self.mesh.axis_names:
            return phys
        return None


_ACTIVE: ShardingCtx | None = None


def default_rules(multi_pod: bool) -> dict:
    dp = ("pod", "data") if multi_pod else ("data",)
    return {"batch": dp, "fsdp": dp, "tp": "model",
            "act_seq": "model", "kv_feat": "model", "expert": "model"}


def serve_rules(multi_pod: bool) -> dict:
    """Inference sharding profile (§Perf iteration): weights tensor-parallel
    over `model` only, replicated across the DP axes — no per-token FSDP
    all-gathers; batch/caches still split over DP."""
    r = default_rules(multi_pod)
    r["fsdp"] = None
    return r


_AXIS_ORDER = ("pod", "data", "model")


def build_mesh(spec: str | int | None = None, *, pod: int | None = None,
               data: int | None = None, model: int | None = None,
               devices=None) -> Mesh:
    """2-D/3-D mesh builder over (pod ×) data × model, rule-driven like the
    logical-axis rules above: the axis *names* are what `default_rules` /
    `serve_rules` map onto, so any mesh built here composes with
    `activate()` (a `pod` axis switches on the multi-pod rule set).

    Accepted specs (string forms are what `--shard` forwards):
      build_mesh(4)  / build_mesh("4")       → data-filled × 4-way model
      build_mesh("2x4") / build_mesh("2x2x2")→ (data, model) / (pod, data,
                                               model) shapes
      build_mesh("data=2,model=4")           → named axes, any subset
      build_mesh(model=4)                    → keyword form of the same

    An omitted `data` is filled with the remaining devices; `pod` appears
    only when requested, keeping 2-D meshes 2-D.
    """
    if spec is not None:
        if pod is not None or data is not None or model is not None:
            raise ValueError("pass a spec or keyword axes, not both")
        named = {}
        s = str(spec).strip()
        if "=" in s:
            for part in s.split(","):
                name, _, val = part.partition("=")
                if name.strip() not in _AXIS_ORDER:
                    raise ValueError(f"unknown mesh axis {name.strip()!r} "
                                     f"(expected {_AXIS_ORDER})")
                named[name.strip()] = int(val)
        elif "x" in s:
            dims = [int(v) for v in s.split("x")]
            if len(dims) not in (2, 3):
                raise ValueError(f"mesh spec {s!r} must be 2-D or 3-D")
            named = dict(zip(_AXIS_ORDER[-len(dims):], dims))
        else:
            named = {"model": int(s)}
        pod, data, model = (named.get(a) for a in _AXIS_ORDER)

    for name, val in zip(_AXIS_ORDER, (pod, data, model)):
        if val is not None and val < 1:
            raise ValueError(f"mesh axis {name}={val} must be >= 1 "
                             f"(omit the axis to disable it)")
    data_explicit = data is not None
    devs = np.asarray(devices if devices is not None else jax.devices())
    model = model or 1
    fixed = (pod or 1) * model
    if data is None:
        if devs.size % fixed:
            raise ValueError(f"{devs.size} devices not divisible by "
                             f"pod*model={fixed}")
        data = devs.size // fixed
    shape = tuple(v for v in (pod, data, model) if v is not None)
    axes = tuple(a for a, v in zip(_AXIS_ORDER, (pod, data, model))
                 if v is not None)
    need = int(np.prod(shape))
    if need > devs.size:
        raise ValueError(f"mesh {dict(zip(axes, shape))} needs {need} "
                         f"devices, only {devs.size} available")
    if need < devs.size and data_explicit:
        # a fully-explicit spec that underfills is usually a typo'd
        # throughput loss, not intent — flag it (an inferred data axis
        # always fills, so this only fires on explicit specs)
        warnings.warn(f"mesh {dict(zip(axes, shape))} uses {need} of "
                      f"{devs.size} devices", stacklevel=2)
    return Mesh(devs[:need].reshape(shape), axes)


def activate(mesh: Mesh, rules: dict | None = None) -> ShardingCtx:
    global _ACTIVE
    multi_pod = "pod" in mesh.axis_names
    _ACTIVE = ShardingCtx(mesh, rules or default_rules(multi_pod))
    return _ACTIVE


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> ShardingCtx | None:
    return _ACTIVE


def constrain(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op when inactive).

    Axes whose size doesn't divide the assigned mesh axes are silently
    dropped to None (e.g. 8 KV heads on a 16-way model axis)."""
    ctx = _ACTIVE
    if ctx is None:
        return x
    spec = ctx.resolve(*logical)
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        size = 1
        for a in ((ax,) if isinstance(ax, str) else ax):
            size *= ctx.mesh.shape[a]
        fixed.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter partition specs (name-based rules)
# ---------------------------------------------------------------------------

# (regex on the dot-joined path) -> logical axes per trailing dims.
# Matching is last-rule-wins; dims are right-aligned (leading stack/period
# axes get None automatically).
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embedding$",              ("tp", "fsdp")),
    (r"unembed$",                ("fsdp", "tp")),
    (r"\b(wq|wk|wv)$",           ("fsdp", "tp")),
    (r"\bwo$",                   ("tp", "fsdp")),
    (r"\b(w_gate|w_up)$",        ("fsdp", "tp")),
    (r"\bw_down$",               ("tp", "fsdp")),
    (r"\brouter$",               (None, None)),
    # MoE experts (E, d, f) / (E, f, d): EP on experts + FSDP on d
    (r"moe.*\b(w_gate|w_up)$",   ("expert", "fsdp", None)),
    (r"moe.*\bw_down$",          ("expert", None, "fsdp")),
    # MLA
    (r"\bw_dq$",                 ("fsdp", None)),
    (r"\bw_uq$",                 (None, "tp")),
    (r"\bw_dkv$",                ("fsdp", None)),
    (r"\bw_kr$",                 (None, None)),
    (r"\b(w_uk|w_uv)$",          (None, "tp")),
    # mamba
    (r"\bw_in$",                 ("fsdp", "tp")),
    (r"\bconv_w$",               (None, "tp")),
    (r"\b(conv_b|d_skip|dt_bias)$", ("tp",)),
    (r"\ba_log$",                ("tp", None)),
    (r"\bw_bc$",                 ("tp", None)),
    (r"\bw_dt_down$",            ("tp", None)),
    (r"\bw_dt_up$",              (None, "tp")),
    # xlstm
    (r"\bw_if$",                 (None, None)),
    (r"\b(w_gates|r_gates)$",    ("fsdp", "tp")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return ".".join(parts)


def spec_for(path_str: str, ndim: int) -> tuple:
    # QuantizedTensor leaves: the .values/.scale arrays inherit the parent
    # weight's rule (right-aligned; non-divisible dims drop to None later).
    path_str = re.sub(r"\.(values|scale)$", "", path_str)
    chosen = None
    for pattern, axes in _PARAM_RULES:
        if re.search(pattern, path_str):
            chosen = axes
    if chosen is None:
        return (None,) * ndim
    pad = ndim - len(chosen)
    if pad < 0:        # param has fewer dims than rule (shouldn't happen)
        return (None,) * ndim
    return (None,) * pad + tuple(chosen)


def param_specs(params: Any) -> Any:
    """Logical spec pytree mirroring `params`."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(_path_str(path), leaf.ndim), params)


def param_shardings(params: Any, ctx: ShardingCtx | None = None) -> Any:
    """NamedSharding pytree for jit in_shardings (divisibility-checked)."""
    ctx = ctx or _ACTIVE
    specs = param_specs(params)

    def to_sharding(leaf, logical):
        fixed = []
        for dim, name in zip(leaf.shape, logical):
            if name is None:
                fixed.append(None)
                continue
            ax = ctx.rules.get(name)
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in ((ax,) if isinstance(ax, str) else ax):
                size *= ctx.mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(ctx.mesh, P(*fixed))

    return jax.tree_util.tree_map(to_sharding, params, specs)
