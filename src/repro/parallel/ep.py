"""Expert-parallel quantized einsum — §VI many-tile scale-out for MoE.

The MoE expert matmul is a batched (E, C, d)·(E, d, f) einsum.  Two ways to
cut it across a mesh, both bit-exact against the single-device serving path
(`bl.serve_einsum_edf`) because every cross-shard reduction happens on the
int32 accumulator before the one dequant epilogue:

  * **partition="e" (expert-parallel)** — each shard owns E/n experts (the
    weight slices `param_specs` already places on the `model` axis) and the
    matching capacity-buffer slices.  Expert compute is embarrassingly
    parallel: no reduction at all, so exactness is structural.
  * **partition="d" (contraction-parallel)** — the TP analogue: activations
    are quantized *globally* (per (e,c) row over the full d), each shard
    multiplies its d-slice with `bl.edf_accumulate` (unit-scale int32 mode),
    and an integer `psum` joins the partials — the dummy-array Accumulator
    row across devices.

Both compose with a `dp_axis` that additionally shards the capacity axis C
(rows are independent), giving DP×EP / DP×TP meshes.

`ep_moe` is the full expert-parallel MoE layer (tokens AND experts sharded
over the EP axis) with two token-dispatch modes:

  * **dispatch="global"** — exact: the *global* rank-in-expert is recovered
    from an all-gathered per-shard count scan, every source scatters into
    full (E, C, d) capacity buffers that an `all_to_all` delivers and the
    owner sums, and the combine `all_gather`s the expert outputs (every
    source token may need any owner's rows at global capacity).  Bit-exact
    vs the single-device `moe()` — drops included.
  * **dispatch="per_source"** — the GShard-style lossy fast path: each
    source packs at most `C_src = ceil(C / n)` tokens per destination
    expert into fixed-size buffers (token values + an int32 sidecar of
    expert ids / source ranks / validity), one `all_to_all` delivers them,
    experts compute on the concatenated per-source rows, and a *mirrored*
    per-source-capacity `all_to_all` scatters results straight back to
    their sources.  No count scan and no all-gather, so per-device dispatch
    volume drops from O(E·C) to O(E·C/n) — at the cost of over-capacity
    drops decided purely shard-locally.

    Tie-break semantics (load-bearing for the property tests): within a
    shard, capacity is granted in (token, k-slot) order via the stable
    argsort rank — earlier assignments win; a token is dropped iff its
    *shard-local* rank-in-expert ≥ C_src.  Global occupancy never causes
    drops, so the drop mask of shard s depends only on shard s's tokens.
    `per_source_reference` replays exactly this rule on one device, which
    makes the lossy path testable bit-exactly (values AND drop mask).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bramac_linear as bl
from repro.core import quant
from repro.parallel import sharding
from repro.parallel.compat import shard_map


def _ep_axis(mesh: Mesh, axis: str | None) -> str:
    """Resolve the physical EP axis: explicit arg > active `expert` rule >
    "model"."""
    if axis is not None:
        return axis
    ctx = sharding.active()
    if ctx is not None:
        phys = ctx.phys_axis("expert")
        if phys is not None:
            return phys
    return "model"


def shardable(x: jax.Array, ctx=None) -> bool:
    """True when the active (or given) sharding ctx can expert-shard an
    (E, C, d) buffer: a string `expert` rule whose mesh axis divides E."""
    ctx = ctx or sharding.active()
    if ctx is None:
        return False
    phys = ctx.phys_axis("expert")
    if phys is None:
        return False
    return x.shape[0] % ctx.mesh.shape[phys] == 0


def layer_shardable(x: jax.Array, cfg, ctx=None) -> bool:
    """True when the full `ep_moe` layer can run under the active (or
    given) ctx for a (B, S, d) input: the `expert` rule resolves to one
    mesh axis whose size divides both E and T = B·S (tokens and experts
    are both sharded over it)."""
    ctx = ctx or sharding.active()
    if ctx is None:
        return False
    phys = ctx.phys_axis("expert")
    if phys is None:
        return False
    n = ctx.mesh.shape[phys]
    B, S = x.shape[0], x.shape[1]
    return cfg.num_experts % n == 0 and (B * S) % n == 0


def _dequant(acc, x_scale, w_scale, dtype):
    """The single dequant epilogue all partitionings funnel into."""
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(dtype)


def ep_quant_einsum_edf(x: jax.Array, qw: quant.QuantizedTensor, *,
                        mesh: Mesh, axis: str | None = None,
                        partition: str = "e", bits_a: int = 8,
                        dp_axis: str | None = None) -> jax.Array:
    """Sharded quantized expert einsum "ecd,edf->ecf" on `mesh`.

    partition="e": experts sharded (EP), no reduction.
    partition="d": contraction sharded (TP) with int32 partial-sum psum.
    dp_axis: optionally also shard the capacity axis C (DP composition).
    Same logical operands as `bl.serve_einsum_edf`; sharding is applied via
    shard_map in_specs, so callers pass full (or pre-placed) arrays.
    """
    E, C, d = x.shape
    ax = _ep_axis(mesh, axis)
    n = mesh.shape[ax]
    wv = qw.unpacked_values()                               # (E, d|f, f|d)
    ws = jnp.broadcast_to(qw.scale, (E, 1, wv.shape[-1]))
    if dp_axis is not None and C % mesh.shape[dp_axis]:
        raise ValueError(f"C={C} not divisible by {mesh.shape[dp_axis]}-way "
                         f"'{dp_axis}' axis")

    if partition == "e":
        if E % n:
            raise ValueError(f"E={E} not divisible by {n}-way '{ax}' axis")

        def expert_parallel(xb, wvb, wsb):
            # local experts only: per-row activation quantization and the
            # int32 accumulator are untouched by the split — structural
            # bit-exactness.
            qx = quant.quantize(xb, bits_a, axis=-1)
            return _dequant(bl.edf_accumulate(qx.values, wvb),
                            qx.scale, wsb, x.dtype)

        return shard_map(expert_parallel, mesh=mesh,
                         in_specs=(P(ax, dp_axis, None), P(ax, None, None),
                                   P(ax, None, None)),
                         out_specs=P(ax, dp_axis, None),
                         check_vma=False)(x, wv, ws)

    if partition == "d":
        if wv.shape[1] % n:
            raise ValueError(f"d={wv.shape[1]} not divisible by {n}-way "
                             f"'{ax}' axis")
        # quantize with full-row scales BEFORE sharding the contraction, so
        # shard partials are raw int32 (unit-scale mode) and psum is exact.
        qx = quant.quantize(x, bits_a, axis=-1)

        def contraction_parallel(xv, wvb):
            return jax.lax.psum(bl.edf_accumulate(xv, wvb), ax)

        acc = shard_map(contraction_parallel, mesh=mesh,
                        in_specs=(P(None, dp_axis, ax), P(None, ax, None)),
                        out_specs=P(None, dp_axis, None),
                        check_vma=False)(qx.values, wv)
        return _dequant(acc, qx.scale, ws, x.dtype)

    raise ValueError(f"partition must be 'e' or 'd', got {partition!r}")


# ---------------------------------------------------------------------------
# Full expert-parallel MoE layer
# ---------------------------------------------------------------------------

def _moe_weights(p, E):
    """(quantized, flat weight list) shared by `ep_moe` and the reference.

    Quantized leaves are unpacked once outside the shard_map so in_specs
    can slice them; scales are broadcast to (E, 1, f) for the same reason.
    """
    quantized = isinstance(p["w_gate"], quant.QuantizedTensor)
    if quantized:
        def unpack(qw):
            wv = qw.unpacked_values()
            return wv, jnp.broadcast_to(qw.scale, (E, 1, wv.shape[-1]))
        weights = [a for name in ("w_gate", "w_up", "w_down")
                   for a in unpack(p[name])]
    else:
        weights = [p["w_gate"], p["w_up"], p["w_down"]]
    return quantized, weights


def _expert_ffn(buf, weights, quantized, bits_a):
    """gate/up/silu/down on an (E', C', d) buffer — the one expert-compute
    body every dispatch mode and the reference funnel through, so their
    bit-exactness is structural (activation quantization is per row)."""
    if quantized:
        gv, gs, uv, us, dv, ds = weights

        def mm(xb, wv, ws):
            qx = quant.quantize(xb, bits_a, axis=-1)
            return _dequant(bl.edf_accumulate(qx.values, wv),
                            qx.scale, ws, xb.dtype)

        g, u = mm(buf, gv, gs), mm(buf, uv, us)
        return mm(jax.nn.silu(g) * u, dv, ds)
    gv, uv, dv = weights
    g = jnp.einsum("ecd,edf->ecf", buf, gv)
    u = jnp.einsum("ecd,edf->ecf", buf, uv)
    return jnp.einsum("ecd,edf->ecf", jax.nn.silu(g) * u, dv)


def ep_moe(p, x, cfg, *, mesh: Mesh, axis: str | None = None,
           capacity_factor: float | None = None, bits_a: int = 8,
           dispatch: str = "global", return_drops: bool = False):
    """Expert-parallel `models.moe.moe`: x (B, S, d) → (out, aux_loss).

    Tokens AND experts are sharded over the EP axis; `dispatch` selects the
    token movement (see the module docstring):

      * "global"     — exact global-capacity buffers: all-gathered count
        scan for the global rank-in-expert, all_to_all dispatch summed at
        the owner, all_gather combine.  Bit-exact vs single-device `moe()`.
      * "per_source" — GShard-style per-source capacity C_src = ceil(C/n):
        purely local ranks, one all_to_all out and a mirrored all_to_all
        back, no gather.  Lossy (shard-local over-capacity drops);
        bit-exact vs `per_source_reference` — drop mask included.

    Weights may be float or serving-quantized (`QuantizedTensor`) — the
    quantized path is bit-exact vs single-device `moe()` for 2/4/8-bit.
    With `return_drops=True` a third output gives the (T, k) keep mask
    (shard-major token order), for drop accounting and the parity tests.
    `capacity_factor=None` resolves to `cfg.moe_capacity_factor`, so a
    direct ep_moe call can never silently disagree with the dense path.
    """
    from repro.models.moe import _rank_in_expert_sort, moe_capacity

    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    ax = _ep_axis(mesh, axis)
    n = mesh.shape[ax]
    if E % n or T % n:
        raise ValueError(f"E={E} and T={T} must divide the {n}-way "
                         f"'{ax}' axis")
    if dispatch not in ("global", "per_source"):
        raise ValueError(f"dispatch must be 'global' or 'per_source', "
                         f"got {dispatch!r}")
    C = moe_capacity(T, E, k, capacity_factor)
    Cs = -(-C // n)                                         # ceil(C / n)
    El = E // n
    xf = x.reshape(T, d)

    quantized, weights = _moe_weights(p, E)
    w_specs = (P(ax, None, None),) * len(weights)

    def shard_fn(xl, router, *w):
        Tl = xl.shape[0]
        logits = xl.astype(jnp.float32) @ router            # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        a = top_i.reshape(Tl * k)
        xk = jnp.repeat(xl, k, axis=0)                      # (Tl*k, d)

        if dispatch == "global":
            # ---- global capacity dispatch from local routing ----
            counts = jnp.bincount(a, length=E)
            all_counts = jax.lax.all_gather(counts, ax)     # (n, E)
            me = jax.lax.axis_index(ax)
            before = jnp.sum(jnp.where(jnp.arange(n)[:, None] < me,
                                       all_counts, 0), axis=0)  # (E,)
            pos = _rank_in_expert_sort(a, E) + before[a]    # global rank
            keep = pos < C
            pos_c = jnp.where(keep, pos, C - 1)

            buf = jnp.zeros((E, C, d), x.dtype)
            buf = buf.at[a, pos_c].add(jnp.where(keep[:, None], xk, 0))
            # dispatch: chunk e' of `buf` is this shard's contribution to
            # the experts shard e' owns — all_to_all delivers, owner sums
            # sources (dropped tokens were zeroed above, so the sum is
            # drop-exact).
            buf = jax.lax.all_to_all(buf.reshape(n, El, C, d), ax,
                                     split_axis=0, concat_axis=0)
            buf = jnp.sum(buf, axis=0)                      # (El, C, d)

            ye = _expert_ffn(buf, w, quantized, bits_a)     # (El, C, d)

            # combine: the gather half of the scatter-gather — every source
            # needs every owner's rows (owner order == axis order, matching
            # the single-device buffer layout).
            ye = jax.lax.all_gather(ye, ax, axis=0, tiled=True)  # (E, C, d)
            yk = ye[a, pos_c]                               # (Tl*k, d)
        else:
            # ---- per-source capacity dispatch (GShard lossy path) ----
            # ranks are purely LOCAL: no count scan, no gather.  Capacity
            # is granted in (token, k-slot) order (stable argsort), and a
            # token is dropped iff its shard-local rank ≥ C_src.
            pos = _rank_in_expert_sort(a, E)
            keep = pos < Cs
            pos_c = jnp.where(keep, pos, Cs - 1)

            buf = jnp.zeros((E, Cs, d), x.dtype)
            buf = buf.at[a, pos_c].add(jnp.where(keep[:, None], xk, 0))
            # int32 sidecar rides the same scatter: (expert id, source
            # rank, valid) — the GShard packed-buffer format, where routing
            # metadata travels WITH the values so the owner never has to
            # reconstruct it from global state.  Kept (a, pos_c) pairs are
            # unique, so add==set; dropped assignments add zeros.
            meta = jnp.zeros((E, Cs, 3), jnp.int32)
            meta = meta.at[a, pos_c].add(
                jnp.where(keep[:, None],
                          jnp.stack([a, pos, jnp.ones_like(a)], axis=-1),
                          0))
            # one all_to_all each way: chunk e' of `buf` goes to the shard
            # owning experts e' — received rows stay source-major, so the
            # owner concatenates instead of summing.
            buf = jax.lax.all_to_all(buf.reshape(n, El, Cs, d), ax,
                                     split_axis=0, concat_axis=0)
            meta = jax.lax.all_to_all(meta.reshape(n, El, Cs, 3), ax,
                                      split_axis=0, concat_axis=0)
            # validity mask enforces the "only packed rows contribute"
            # contract (unwritten rows are already zero, so this is a
            # bit-exact no-op — kept as the invariant, not for values).
            buf = jnp.where(meta[..., 2:3] > 0, buf, 0)     # (n, El, Cs, d)
            buf = buf.transpose(1, 0, 2, 3).reshape(El, n * Cs, d)

            ye = _expert_ffn(buf, w, quantized, bits_a)     # (El, n*Cs, d)

            # mirrored combine: owner o's rows for source s go straight
            # back to shard s; received chunks are owner-major, which IS
            # the global expert order.
            ye = ye.reshape(El, n, Cs, d).transpose(1, 0, 2, 3)
            ye = jax.lax.all_to_all(ye, ax, split_axis=0, concat_axis=0)
            yk = ye.reshape(E, Cs, d)[a, pos_c]             # (Tl*k, d)

        w_tok = (top_p.reshape(Tl * k).astype(x.dtype)
                 * keep.astype(x.dtype))[:, None]
        out = jnp.sum((yk * w_tok).reshape(Tl, k, d), axis=1)

        # ---- Switch load-balance loss (psum'd partial sums) ----
        frac_tokens = jax.lax.psum(
            jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32),
                    axis=(0, 1)), ax) / (T * k)
        frac_probs = jax.lax.psum(jnp.sum(probs, axis=0), ax) / T
        aux = E * jnp.sum(frac_tokens * frac_probs)
        if return_drops:
            return out, aux, keep.reshape(Tl, k)
        return out, aux

    out_specs = (P(ax, None), P())
    if return_drops:
        out_specs += (P(ax, None),)
    res = shard_map(shard_fn, mesh=mesh,
                    in_specs=(P(ax, None), P(None, None), *w_specs),
                    out_specs=out_specs,
                    check_vma=False)(xf, p["router"], *weights)
    if return_drops:
        out, aux, keep = res
        return out.reshape(B, S, d), aux, keep
    out, aux = res
    return out.reshape(B, S, d), aux


def per_source_reference(p, x, cfg, *, ep_size: int,
                         capacity_factor: float | None = None,
                         bits_a: int = 8):
    """Single-device pure-JAX simulator of `ep_moe(dispatch="per_source")`.

    Replays the exact shard decomposition an `ep_size`-way EP axis would
    induce — tokens in shard-major blocks, shard-local stable-argsort
    ranks, C_src = ceil(C / ep_size) drops — and runs the identical
    `_expert_ffn` body on identically-ordered buffers, so outputs AND the
    drop mask match the distributed path bit for bit.  This is what makes
    the lossy path testable without a mesh.

    Returns (out (B,S,d), aux_loss, keep (T,k) bool in shard-major order).
    """
    from repro.models.moe import _rank_in_expert_sort, moe_capacity

    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    n = ep_size
    if E % n or T % n:
        raise ValueError(f"E={E} and T={T} must divide ep_size={n}")
    C = moe_capacity(T, E, k, capacity_factor)
    Cs = -(-C // n)
    Tl = T // n
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # shard-local ranks: each shard-major block of Tl*k assignments is
    # ranked independently — exactly shard_fn's local argsort.
    a = top_i.reshape(n, Tl * k)
    pos = jax.vmap(lambda v: _rank_in_expert_sort(v, E))(a)
    keep = pos < Cs
    pos_c = jnp.where(keep, pos, Cs - 1)

    xk = jnp.repeat(xf, k, axis=0).reshape(n, Tl * k, d)
    buf = jax.vmap(lambda ai, pi, xi, ki:
                   jnp.zeros((E, Cs, d), x.dtype).at[ai, pi].add(
                       jnp.where(ki[:, None], xi, 0)))(a, pos_c, xk, keep)
    # (n, E, Cs, d) → source-major rows per expert, the owners' concat order
    buf = buf.transpose(1, 0, 2, 3).reshape(E, n * Cs, d)

    quantized, weights = _moe_weights(p, E)
    ye = _expert_ffn(buf, weights, quantized, bits_a)       # (E, n*Cs, d)

    ybuf = ye.reshape(E, n, Cs, d).transpose(1, 0, 2, 3)    # (n, E, Cs, d)
    yk = jax.vmap(lambda yb, ai, pi: yb[ai, pi])(ybuf, a, pos_c)
    w_tok = (top_p.reshape(n, Tl * k).astype(x.dtype)
             * keep.astype(x.dtype))[..., None]
    out = jnp.sum((yk * w_tok).reshape(n, Tl, k, d), axis=2)

    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(B, S, d), aux, keep.reshape(T, k)
