"""Expert-parallel quantized einsum — §VI many-tile scale-out for MoE.

The MoE expert matmul is a batched (E, C, d)·(E, d, f) einsum.  Two ways to
cut it across a mesh, both bit-exact against the single-device serving path
(`bl.serve_einsum_edf`) because every cross-shard reduction happens on the
int32 accumulator before the one dequant epilogue:

  * **partition="e" (expert-parallel)** — each shard owns E/n experts (the
    weight slices `param_specs` already places on the `model` axis) and the
    matching capacity-buffer slices.  Expert compute is embarrassingly
    parallel: no reduction at all, so exactness is structural.
  * **partition="d" (contraction-parallel)** — the TP analogue: activations
    are quantized *globally* (per (e,c) row over the full d), each shard
    multiplies its d-slice with `bl.edf_accumulate` (unit-scale int32 mode),
    and an integer `psum` joins the partials — the dummy-array Accumulator
    row across devices.

Both compose with a `dp_axis` that additionally shards the capacity axis C
(rows are independent), giving DP×EP / DP×TP meshes.

`ep_moe` is the full expert-parallel MoE layer: tokens sharded over the EP
axis, routing computed locally, global rank-in-expert recovered with an
all-gathered count scan, and the dispatch/combine scatter-gather made
explicit collectives (dispatch: per-destination capacity buffers delivered
by `all_to_all` and summed at the owner; combine: the dual `all_gather` of
expert outputs).  Output is bit-exact vs the single-device `moe()` — drops
included, since dropped tokens contribute exact zeros on both paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bramac_linear as bl
from repro.core import quant
from repro.parallel import sharding
from repro.parallel.compat import shard_map


def _ep_axis(mesh: Mesh, axis: str | None) -> str:
    """Resolve the physical EP axis: explicit arg > active `expert` rule >
    "model"."""
    if axis is not None:
        return axis
    ctx = sharding.active()
    if ctx is not None:
        phys = ctx.rules.get("expert")
        if isinstance(phys, str):
            return phys
    return "model"


def shardable(x: jax.Array, ctx=None) -> bool:
    """True when the active (or given) sharding ctx can expert-shard an
    (E, C, d) buffer: a string `expert` rule whose mesh axis divides E."""
    ctx = ctx or sharding.active()
    if ctx is None:
        return False
    phys = ctx.rules.get("expert")
    if not isinstance(phys, str) or phys not in ctx.mesh.axis_names:
        return False
    return x.shape[0] % ctx.mesh.shape[phys] == 0


def _dequant(acc, x_scale, w_scale, dtype):
    """The single dequant epilogue all partitionings funnel into."""
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(dtype)


def ep_quant_einsum_edf(x: jax.Array, qw: quant.QuantizedTensor, *,
                        mesh: Mesh, axis: str | None = None,
                        partition: str = "e", bits_a: int = 8,
                        dp_axis: str | None = None) -> jax.Array:
    """Sharded quantized expert einsum "ecd,edf->ecf" on `mesh`.

    partition="e": experts sharded (EP), no reduction.
    partition="d": contraction sharded (TP) with int32 partial-sum psum.
    dp_axis: optionally also shard the capacity axis C (DP composition).
    Same logical operands as `bl.serve_einsum_edf`; sharding is applied via
    shard_map in_specs, so callers pass full (or pre-placed) arrays.
    """
    E, C, d = x.shape
    ax = _ep_axis(mesh, axis)
    n = mesh.shape[ax]
    wv = qw.unpacked_values()                               # (E, d|f, f|d)
    ws = jnp.broadcast_to(qw.scale, (E, 1, wv.shape[-1]))
    if dp_axis is not None and C % mesh.shape[dp_axis]:
        raise ValueError(f"C={C} not divisible by {mesh.shape[dp_axis]}-way "
                         f"'{dp_axis}' axis")

    if partition == "e":
        if E % n:
            raise ValueError(f"E={E} not divisible by {n}-way '{ax}' axis")

        def expert_parallel(xb, wvb, wsb):
            # local experts only: per-row activation quantization and the
            # int32 accumulator are untouched by the split — structural
            # bit-exactness.
            qx = quant.quantize(xb, bits_a, axis=-1)
            return _dequant(bl.edf_accumulate(qx.values, wvb),
                            qx.scale, wsb, x.dtype)

        return shard_map(expert_parallel, mesh=mesh,
                         in_specs=(P(ax, dp_axis, None), P(ax, None, None),
                                   P(ax, None, None)),
                         out_specs=P(ax, dp_axis, None),
                         check_vma=False)(x, wv, ws)

    if partition == "d":
        if wv.shape[1] % n:
            raise ValueError(f"d={wv.shape[1]} not divisible by {n}-way "
                             f"'{ax}' axis")
        # quantize with full-row scales BEFORE sharding the contraction, so
        # shard partials are raw int32 (unit-scale mode) and psum is exact.
        qx = quant.quantize(x, bits_a, axis=-1)

        def contraction_parallel(xv, wvb):
            return jax.lax.psum(bl.edf_accumulate(xv, wvb), ax)

        acc = shard_map(contraction_parallel, mesh=mesh,
                        in_specs=(P(None, dp_axis, ax), P(None, ax, None)),
                        out_specs=P(None, dp_axis, None),
                        check_vma=False)(qx.values, wv)
        return _dequant(acc, qx.scale, ws, x.dtype)

    raise ValueError(f"partition must be 'e' or 'd', got {partition!r}")


# ---------------------------------------------------------------------------
# Full expert-parallel MoE layer
# ---------------------------------------------------------------------------

def ep_moe(p, x, cfg, *, mesh: Mesh, axis: str | None = None,
           capacity_factor: float = 1.25, bits_a: int = 8):
    """Expert-parallel `models.moe.moe`: x (B, S, d) → (out, aux_loss).

    Tokens AND experts are sharded over the EP axis.  Each shard routes its
    local tokens, recovers the *global* rank-in-expert from an all-gathered
    per-shard count scan (token order is shard-major, so global rank =
    local rank + earlier shards' counts — identical to the single-device
    ranks), then builds per-destination capacity buffers that an
    `all_to_all` delivers to the expert owners; the combine `all_gather`s
    the expert outputs back (every source token may need any owner's rows
    at global capacity — a per-source-capacity all_to_all combine is the
    lossy GShard-style fast path left on the ROADMAP).  Weights may be
    float or serving-quantized (`QuantizedTensor`) — the quantized path is
    bit-exact vs single-device `moe()` for 2/4/8-bit.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    ax = _ep_axis(mesh, axis)
    n = mesh.shape[ax]
    if E % n or T % n:
        raise ValueError(f"E={E} and T={T} must divide the {n}-way "
                         f"'{ax}' axis")
    C = int(max(1, round(T * k / E * capacity_factor)))
    El = E // n
    xf = x.reshape(T, d)

    quantized = isinstance(p["w_gate"], quant.QuantizedTensor)
    if quantized:
        def unpack(qw):
            wv = qw.unpacked_values()
            return wv, jnp.broadcast_to(qw.scale, (E, 1, wv.shape[-1]))
        weights = [a for name in ("w_gate", "w_up", "w_down")
                   for a in unpack(p[name])]
        w_specs = (P(ax, None, None),) * 6

        def mm(xb, wv, ws):
            qx = quant.quantize(xb, bits_a, axis=-1)
            return _dequant(bl.edf_accumulate(qx.values, wv),
                            qx.scale, ws, xb.dtype)
    else:
        weights = [p["w_gate"], p["w_up"], p["w_down"]]
        w_specs = (P(ax, None, None),) * 3

        def mm(xb, wv):
            return jnp.einsum("ecd,edf->ecf", xb, wv)

    def shard_fn(xl, router, *w):
        Tl = xl.shape[0]
        logits = xl.astype(jnp.float32) @ router            # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        # ---- global capacity dispatch from local routing ----
        from repro.models.moe import _rank_in_expert_sort
        a = top_i.reshape(Tl * k)
        counts = jnp.bincount(a, length=E)
        all_counts = jax.lax.all_gather(counts, ax)         # (n, E)
        me = jax.lax.axis_index(ax)
        before = jnp.sum(jnp.where(jnp.arange(n)[:, None] < me,
                                   all_counts, 0), axis=0)  # (E,)
        pos = _rank_in_expert_sort(a, E) + before[a]        # global rank
        keep = pos < C
        pos_c = jnp.where(keep, pos, C - 1)

        xk = jnp.repeat(xl, k, axis=0)                      # (Tl*k, d)
        buf = jnp.zeros((E, C, d), x.dtype)
        buf = buf.at[a, pos_c].add(jnp.where(keep[:, None], xk, 0))
        # dispatch: chunk e' of `buf` is this shard's contribution to the
        # experts shard e' owns — all_to_all delivers, owner sums sources
        # (dropped tokens were zeroed above, so the sum is drop-exact).
        buf = jax.lax.all_to_all(buf.reshape(n, El, C, d), ax,
                                 split_axis=0, concat_axis=0)
        buf = jnp.sum(buf, axis=0)                          # (El, C, d)

        # ---- local expert compute ----
        if quantized:
            gv, gs, uv, us, dv, ds = w
            g, u = mm(buf, gv, gs), mm(buf, uv, us)
            ye = mm(jax.nn.silu(g) * u, dv, ds)
        else:
            gv, uv, dv = w
            g, u = mm(buf, gv), mm(buf, uv)
            ye = mm(jax.nn.silu(g) * u, dv)                 # (El, C, d)

        # combine: the gather half of the scatter-gather — every source
        # needs every owner's rows (owner order == axis order, matching
        # the single-device buffer layout).
        ye = jax.lax.all_gather(ye, ax, axis=0, tiled=True)  # (E, C, d)
        yk = ye[a, pos_c]                                   # (Tl*k, d)
        w_tok = (top_p.reshape(Tl * k).astype(x.dtype)
                 * keep.astype(x.dtype))[:, None]
        out = jnp.sum((yk * w_tok).reshape(Tl, k, d), axis=1)

        # ---- Switch load-balance loss (psum'd partial sums) ----
        frac_tokens = jax.lax.psum(
            jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32),
                    axis=(0, 1)), ax) / (T * k)
        frac_probs = jax.lax.psum(jnp.sum(probs, axis=0), ax) / T
        aux = E * jnp.sum(frac_tokens * frac_probs)
        return out, aux

    out, aux = shard_map(shard_fn, mesh=mesh,
                         in_specs=(P(ax, None), P(None, None), *w_specs),
                         out_specs=(P(ax, None), P()),
                         check_vma=False)(xf, p["router"], *weights)
    return out.reshape(B, S, d), aux
