# Sharded execution: logical-axis rules + DP×TP(×EP) mesh builder
# (sharding), version-portable collectives entry points (compat),
# tensor-parallel quantized matmul (tp), expert-parallel quantized einsum
# and MoE layer (ep).
