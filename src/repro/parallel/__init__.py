# Sharded execution: logical-axis rules (sharding), version-portable
# collectives entry points (compat), tensor-parallel quantized matmul (tp).
