"""Deterministic synthetic token pipeline — stateless, shard-aware,
restart/straggler friendly.

Every batch is a pure function of (seed, step), so:
  * restart-after-failure resumes mid-run with zero drift (fault tolerance),
  * any host can regenerate any shard (no data-loader state to checkpoint),
  * skip-ahead is O(1) (straggler mitigation never re-reads).

The synthetic LM stream embeds an order-k Markov structure so the training
loss actually decreases (examples/train_tiny_lm.py demonstrates this).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 1


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random Markov transition with low entropy → learnable
        k = min(cfg.vocab_size, 512)
        trans = rng.dirichlet(np.full(k, 0.05), size=k).astype(np.float32)
        self._trans = trans
        self._k = k

    def batch_np(self, step: int) -> dict:
        """Global (unsharded) batch for `step` — deterministic."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._k, B)
        # vectorized Markov walk
        u = rng.random((B, S), np.float32)
        cdf = np.cumsum(self._trans, axis=1)
        for t in range(S):
            toks[:, t + 1] = np.argmax(
                cdf[toks[:, t]] > u[:, t:t + 1], axis=1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch(self, step: int, sharding=None) -> dict:
        b = self.batch_np(step)
        if sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in b.items()}
        return {k: jax.device_put(v, sharding) for k, v in b.items()}
